//! HTTP load generator: drives the [`super::HttpServer`] front door
//! over loopback (or any address) with closed-loop or rate-paced
//! open-loop clients, and reports achieved QPS and latency quantiles —
//! the MLPerf server-scenario harness shape, std-only like the server.
//!
//! Closed loop (`target_qps == 0`): each of `concurrency` clients fires
//! its next request the moment the previous answer lands — measures
//! saturation throughput. Open loop (`target_qps > 0`): request *i* is
//! due at `t0 + i/qps` on a global schedule regardless of completions,
//! so a server that can't keep up shows ballooning latency instead of a
//! flattering slowdown of the offered load. (With a finite client pool
//! the offered rate degrades once all clients are stuck waiting — a
//! paced approximation of a true open loop; raise `concurrency` until
//! achieved QPS reaches the target.)
//!
//! [`run_generate`] is the decode twin: a closed-loop driver for
//! `POST :generate` that parses each answer's `per_token_ms` series and
//! reports tokens/sec plus per-token p50/p95 alongside the usual
//! request-level classes — shared by `bench-serve --scenario generate`
//! and the soak paths.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::json;
use crate::rng::Pcg64;
use crate::stats::quantile_sorted;

/// What to drive, how hard.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Server address, e.g. `"127.0.0.1:8080"`.
    pub addr: String,
    /// Model to hit (`POST /v1/models/{model}:predict`).
    pub model: String,
    /// Elements per example (the model's flat input size).
    pub in_elems: usize,
    /// Total requests across all clients.
    pub requests: usize,
    /// Concurrent client connections (each a thread + keep-alive socket).
    pub concurrency: usize,
    /// Open-loop target rate; `0.0` = closed loop.
    pub target_qps: f64,
    /// Retry budget per logical request: a 429/503 answer is retried up
    /// to this many times with jittered backoff honouring the server's
    /// `Retry-After` header. Retries are reported separately
    /// ([`LoadReport::retries`]) and never count as fresh offered load.
    pub retries: usize,
}

/// The outcome: status-class counts and latency quantiles over the
/// completed (HTTP 200) requests.
#[derive(Debug, Clone, Default)]
pub struct LoadReport {
    pub sent: usize,
    pub ok: usize,
    /// 429s — the server's backpressure answer, counted apart from
    /// other 4xx so a saturation run is legible at a glance.
    pub throttled: usize,
    pub client_errors: usize,
    pub server_errors: usize,
    /// 503s — deadline sheds / worker-unavailable answers. A *subset*
    /// of `server_errors` (the class sums are unchanged), split out so
    /// a continuous-batching run shows its shed rate at a glance.
    pub shed: usize,
    pub transport_errors: usize,
    /// Retry attempts spent on 429/503 answers (when
    /// [`LoadSpec::retries`] > 0). Counted apart from `sent`: a logical
    /// request is offered once however many times it is retried, and
    /// only its final answer lands in the status classes above.
    pub retries: usize,
    pub wall_s: f64,
    /// Completed-request throughput (`ok / wall_s`).
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub max_ms: f64,
}

impl LoadReport {
    /// One-line human rendering.
    pub fn render(&self) -> String {
        format!(
            "{} ok / {} sent in {:.2}s = {:.1} req/s  (429 {}, 4xx {}, 5xx {} [503 {}], io {}, retries {})  p50 {:.1} ms  p95 {:.1} ms  max {:.1} ms",
            self.ok,
            self.sent,
            self.wall_s,
            self.qps,
            self.throttled,
            self.client_errors,
            self.server_errors,
            self.shed,
            self.transport_errors,
            self.retries,
            self.p50_ms,
            self.p95_ms,
            self.max_ms,
        )
    }

    /// Machine-readable rendering (the `bench_serve.json` building
    /// block).
    pub fn to_json(&self) -> json::Value {
        json::obj(vec![
            ("sent", json::num(self.sent as f64)),
            ("ok", json::num(self.ok as f64)),
            ("throttled_429", json::num(self.throttled as f64)),
            ("client_errors_4xx", json::num(self.client_errors as f64)),
            ("server_errors_5xx", json::num(self.server_errors as f64)),
            ("shed_503", json::num(self.shed as f64)),
            ("transport_errors", json::num(self.transport_errors as f64)),
            ("retries", json::num(self.retries as f64)),
            ("wall_s", json::num(self.wall_s)),
            ("qps", json::num(self.qps)),
            ("p50_ms", json::num(self.p50_ms)),
            ("p95_ms", json::num(self.p95_ms)),
            ("max_ms", json::num(self.max_ms)),
        ])
    }
}

/// What to decode, how hard (closed loop only — a decode request holds
/// its worker for the whole autoregressive loop, so pacing is the
/// completion rate).
#[derive(Debug, Clone)]
pub struct GenSpec {
    /// Server address, e.g. `"127.0.0.1:8080"`.
    pub addr: String,
    /// Model to hit (`POST /v1/models/{model}:generate`).
    pub model: String,
    /// Prompt tokens per request.
    pub prompt_len: usize,
    /// New tokens requested per decode (`max_new_tokens`).
    pub max_new: usize,
    /// Vocabulary bound for the deterministic prompt ids.
    pub vocab: usize,
    /// Total decode requests across all clients.
    pub requests: usize,
    /// Concurrent client connections.
    pub concurrency: usize,
}

/// The [`run_generate`] outcome: request-level classes/quantiles plus
/// the decode-level view (tokens/sec and per-token quantiles pooled
/// from every 200 answer's `per_token_ms` series).
#[derive(Debug, Clone, Default)]
pub struct GenReport {
    pub load: LoadReport,
    /// Tokens decoded across all 200 answers.
    pub tokens: usize,
    pub tokens_per_s: f64,
    pub tok_p50_ms: f64,
    pub tok_p95_ms: f64,
}

impl GenReport {
    /// One-line human rendering.
    pub fn render(&self) -> String {
        format!(
            "{}  |  {} tokens = {:.1} tok/s  tok p50 {:.3} ms  tok p95 {:.3} ms",
            self.load.render(),
            self.tokens,
            self.tokens_per_s,
            self.tok_p50_ms,
            self.tok_p95_ms,
        )
    }

    pub fn to_json(&self) -> json::Value {
        json::obj(vec![
            ("load", self.load.to_json()),
            ("tokens", json::num(self.tokens as f64)),
            ("tokens_per_s", json::num(self.tokens_per_s)),
            ("tok_p50_ms", json::num(self.tok_p50_ms)),
            ("tok_p95_ms", json::num(self.tok_p95_ms)),
        ])
    }
}

/// A [`run_sharded`] outcome: the merged view plus one report per
/// client worker (each with its own quantiles and completed-QPS share —
/// a skewed worker is visible instead of averaged away).
#[derive(Debug, Clone, Default)]
pub struct ShardedReport {
    pub merged: LoadReport,
    pub workers: Vec<LoadReport>,
}

impl ShardedReport {
    /// Multi-line human rendering: merged first, then per worker.
    pub fn render(&self) -> String {
        let mut out = format!("merged    {}", self.merged.render());
        for (i, w) in self.workers.iter().enumerate() {
            out.push_str(&format!("\nworker {i:>2} {}", w.render()));
        }
        out
    }
}

/// One keep-alive HTTP/1.1 client connection with its read-ahead
/// buffer. This is the crate's one minimal HTTP client — the load
/// generator's workers and `tests/http.rs` both drive the server
/// through it, so there is a single copy of the response-framing logic.
pub struct Conn {
    addr: String,
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Conn {
    pub fn open(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(30)))
            .ok();
        Ok(Conn {
            addr: addr.to_string(),
            stream,
            buf: Vec::new(),
        })
    }

    /// Send one request on the persistent connection and read the full
    /// response: `(status, body)`.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> Result<(u16, String)> {
        let (status, body, _) = self.request_full(method, path, body)?;
        Ok((status, body))
    }

    /// [`Conn::request`] keeping the retryability signal: `(status,
    /// body, retry_after_seconds)` — the parsed `Retry-After` header
    /// when the server sent one (429/503 answers do).
    pub fn request_full(
        &mut self,
        method: &str,
        path: &str,
        body: &str,
    ) -> Result<(u16, String, Option<f64>)> {
        let head = format!(
            "{method} {path} HTTP/1.1\r\nhost: {}\r\ncontent-type: application/json\r\ncontent-length: {}\r\n\r\n",
            self.addr,
            body.len()
        );
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body.as_bytes())?;
        self.stream.flush()?;
        // Read the response head.
        let head_end = loop {
            if let Some(i) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break i;
            }
            let mut chunk = [0u8; 8192];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                bail!("server closed the connection mid-response");
            }
            self.buf.extend_from_slice(&chunk[..n]);
        };
        let head_text = std::str::from_utf8(&self.buf[..head_end])?.to_string();
        let status: u16 = head_text
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow!("malformed status line in {head_text:?}"))?;
        let header = |name: &str| {
            head_text
                .lines()
                .filter_map(|l| l.split_once(':'))
                .find(|(n, _)| n.trim().eq_ignore_ascii_case(name))
                .map(|(_, v)| v.trim().to_string())
        };
        let content_length: usize = header("content-length")
            .and_then(|v| v.parse().ok())
            .unwrap_or(0);
        let retry_after: Option<f64> =
            header("retry-after").and_then(|v| v.parse().ok());
        let total = head_end + 4 + content_length;
        while self.buf.len() < total {
            let mut chunk = [0u8; 8192];
            let n = self.stream.read(&mut chunk)?;
            if n == 0 {
                bail!("server closed the connection mid-body");
            }
            self.buf.extend_from_slice(&chunk[..n]);
        }
        let resp_body =
            String::from_utf8(self.buf[head_end + 4..total].to_vec())?;
        self.buf.drain(..total);
        Ok((status, resp_body, retry_after))
    }
}

/// Per-client tally, merged after the run.
#[derive(Default)]
struct Tally {
    sent: usize,
    ok: usize,
    throttled: usize,
    client_errors: usize,
    server_errors: usize,
    shed: usize,
    transport_errors: usize,
    retries: usize,
    latencies_ms: Vec<f64>,
}

/// Fold a group of tallies into one report over the shared wall clock.
fn report_from<'a>(
    tallies: impl Iterator<Item = &'a Tally>,
    wall_s: f64,
) -> LoadReport {
    let mut report = LoadReport {
        wall_s,
        ..LoadReport::default()
    };
    let mut lat: Vec<f64> = Vec::new();
    for t in tallies {
        report.sent += t.sent;
        report.ok += t.ok;
        report.throttled += t.throttled;
        report.client_errors += t.client_errors;
        report.server_errors += t.server_errors;
        report.shed += t.shed;
        report.transport_errors += t.transport_errors;
        report.retries += t.retries;
        lat.extend_from_slice(&t.latencies_ms);
    }
    lat.sort_by(f64::total_cmp);
    report.qps = report.ok as f64 / wall_s.max(1e-9);
    report.p50_ms = quantile_sorted(&lat, 0.5);
    report.p95_ms = quantile_sorted(&lat, 0.95);
    report.max_ms = lat.last().copied().unwrap_or(0.0);
    report
}

/// Run the load. Blocks until all `spec.requests` have been attempted.
pub fn run(spec: &LoadSpec) -> Result<LoadReport> {
    Ok(run_sharded(spec, 1)?.merged)
}

/// Run the load split across `workers` client groups. The
/// `spec.concurrency` connections are dealt round-robin to the groups;
/// every group draws from the one global request counter (and open-loop
/// schedule), so the split changes *reporting granularity*, not the
/// offered load. Each worker's report has its own quantiles and its
/// share of the completed QPS; `merged` is identical to what [`run`]
/// returns.
pub fn run_sharded(spec: &LoadSpec, workers: usize) -> Result<ShardedReport> {
    if spec.requests == 0 || spec.concurrency == 0 || spec.in_elems == 0 {
        bail!("loadgen: requests, concurrency and in_elems must all be >= 1");
    }
    if workers == 0 || workers > spec.concurrency {
        bail!(
            "loadgen: workers must be in 1..=concurrency (got {workers} \
             workers for {} connections)",
            spec.concurrency
        );
    }
    let path = format!("/v1/models/{}:predict", spec.model);
    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let tallies: Vec<Tally> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for _ in 0..spec.concurrency {
            let next = next.clone();
            let (spec, path) = (spec.clone(), path.clone());
            joins.push(s.spawn(move || client_main(&spec, &path, &next, t0)));
        }
        joins
            .into_iter()
            // Propagate a client-thread panic instead of silently
            // replacing that worker's tally with zeros — an
            // under-reported bench is worse than a loud failure.
            .map(|j| j.join().expect("loadgen client thread panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let merged = report_from(tallies.iter(), wall_s);
    let per_worker = (0..workers)
        .map(|w| {
            report_from(
                tallies.iter().skip(w).step_by(workers),
                wall_s,
            )
        })
        .collect();
    Ok(ShardedReport {
        merged,
        workers: per_worker,
    })
}

fn client_main(
    spec: &LoadSpec,
    path: &str,
    next: &AtomicUsize,
    t0: Instant,
) -> Tally {
    let mut tally = Tally::default();
    let mut conn: Option<Conn> = None;
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= spec.requests {
            return tally;
        }
        if spec.target_qps > 0.0 {
            // Open loop: request i is due at t0 + i/qps.
            let due = Duration::from_secs_f64(i as f64 / spec.target_qps);
            let elapsed = t0.elapsed();
            if due > elapsed {
                std::thread::sleep(due - elapsed);
            }
        }
        let body = body_for(i, spec.in_elems);
        tally.sent += 1;
        let t_req = Instant::now();
        // Per-request jitter stream: keyed by the logical request index
        // so a retrying fleet decorrelates instead of thundering back
        // in lockstep at the Retry-After boundary.
        let mut rng = Pcg64::new(0x10ad_6e11, i as u64);
        let mut outcome = attempt_once(&mut conn, spec, path, &body);
        let mut retry = 0usize;
        while retry < spec.retries && matches!(outcome, Some((429 | 503, _))) {
            // Jittered backoff honouring the server's Retry-After hint
            // (seconds): the hint is the base, doubled per consecutive
            // retry and capped, scaled into [0.5, 1.0) of itself.
            let base = outcome.and_then(|(_, ra)| ra).unwrap_or(0.05).max(0.001);
            let backoff = (base * (1u64 << retry.min(4)) as f64).min(2.0);
            let delay = backoff * rng.uniform(0.5, 1.0) as f64;
            std::thread::sleep(Duration::from_secs_f64(delay));
            retry += 1;
            tally.retries += 1;
            outcome = attempt_once(&mut conn, spec, path, &body);
        }
        // Only the final answer lands in the status classes; latency
        // for a retried request covers its whole lifetime, backoff
        // included (that is what the client experienced).
        match outcome.map(|(code, _)| code) {
            Some(200) => {
                tally.ok += 1;
                tally
                    .latencies_ms
                    .push(t_req.elapsed().as_secs_f64() * 1e3);
            }
            other => tally_failure(other, &mut tally),
        }
    }
}

/// One send with the transparent reconnect: a keep-alive socket the
/// server has since closed (idle timeout, restart) fails the first
/// write or read — retry once on a fresh connection before counting a
/// transport error. Returns `(status, retry_after_seconds)`, or `None`
/// on transport failure.
fn attempt_once(
    conn: &mut Option<Conn>,
    spec: &LoadSpec,
    path: &str,
    body: &str,
) -> Option<(u16, Option<f64>)> {
    for attempt in 0..2 {
        if conn.is_none() {
            match Conn::open(&spec.addr) {
                Ok(c) => *conn = Some(c),
                Err(_) => break,
            }
        }
        let c = conn.as_mut().unwrap();
        match c.request_full("POST", path, body) {
            Ok((code, _, retry_after)) => return Some((code, retry_after)),
            Err(_) => {
                *conn = None;
                if attempt == 1 {
                    break;
                }
            }
        }
    }
    None
}

/// Fold a non-200 outcome into the tally's status classes (shared by
/// the predict and generate client loops).
fn tally_failure(status: Option<u16>, tally: &mut Tally) {
    match status {
        None => tally.transport_errors += 1,
        Some(429) => tally.throttled += 1,
        Some(c) if (400..500).contains(&c) => tally.client_errors += 1,
        Some(503) => {
            // Deadline shed / unavailable: still a 5xx in the class
            // sums, additionally split out.
            tally.server_errors += 1;
            tally.shed += 1;
        }
        Some(_) => tally.server_errors += 1,
    }
}

/// Per-client decode tally: the request-level classes plus the pooled
/// per-token latency series parsed out of each 200 answer.
#[derive(Default)]
struct GenTally {
    tally: Tally,
    per_token_ms: Vec<f64>,
    tokens: usize,
}

/// Run the decode load. Blocks until all `spec.requests` have been
/// attempted; closed loop only (each client fires its next `:generate`
/// the moment the previous answer lands).
pub fn run_generate(spec: &GenSpec) -> Result<GenReport> {
    if spec.requests == 0
        || spec.concurrency == 0
        || spec.prompt_len == 0
        || spec.max_new == 0
    {
        bail!(
            "loadgen: generate needs requests, concurrency, prompt_len \
             and max_new all >= 1"
        );
    }
    let path = format!("/v1/models/{}:generate", spec.model);
    let next = Arc::new(AtomicUsize::new(0));
    let t0 = Instant::now();
    let tallies: Vec<GenTally> = std::thread::scope(|s| {
        let mut joins = Vec::new();
        for _ in 0..spec.concurrency {
            let next = next.clone();
            let (spec, path) = (spec.clone(), path.clone());
            joins.push(s.spawn(move || gen_client_main(&spec, &path, &next)));
        }
        joins
            .into_iter()
            .map(|j| j.join().expect("loadgen generate thread panicked"))
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let load = report_from(tallies.iter().map(|g| &g.tally), wall_s);
    let tokens: usize = tallies.iter().map(|g| g.tokens).sum();
    let mut tok: Vec<f64> = tallies
        .iter()
        .flat_map(|g| g.per_token_ms.iter().copied())
        .collect();
    tok.sort_by(f64::total_cmp);
    Ok(GenReport {
        load,
        tokens,
        tokens_per_s: tokens as f64 / wall_s.max(1e-9),
        tok_p50_ms: quantile_sorted(&tok, 0.5),
        tok_p95_ms: quantile_sorted(&tok, 0.95),
    })
}

fn gen_client_main(
    spec: &GenSpec,
    path: &str,
    next: &AtomicUsize,
) -> GenTally {
    let mut acc = GenTally::default();
    let mut conn: Option<Conn> = None;
    loop {
        let i = next.fetch_add(1, Ordering::Relaxed);
        if i >= spec.requests {
            return acc;
        }
        let body = gen_body_for(i, spec.prompt_len, spec.max_new, spec.vocab);
        acc.tally.sent += 1;
        let t_req = Instant::now();
        // Same one-transparent-reconnect idiom as `client_main`.
        let mut answer = None;
        for attempt in 0..2 {
            if conn.is_none() {
                match Conn::open(&spec.addr) {
                    Ok(c) => conn = Some(c),
                    Err(_) => break,
                }
            }
            let c = conn.as_mut().unwrap();
            match c.request("POST", path, &body) {
                Ok(resp) => {
                    answer = Some(resp);
                    break;
                }
                Err(_) => {
                    conn = None;
                    if attempt == 1 {
                        break;
                    }
                }
            }
        }
        match answer {
            Some((200, resp_body)) => {
                acc.tally.ok += 1;
                acc.tally
                    .latencies_ms
                    .push(t_req.elapsed().as_secs_f64() * 1e3);
                absorb_generate_body(&resp_body, &mut acc);
            }
            other => tally_failure(other.map(|(code, _)| code), &mut acc.tally),
        }
    }
}

/// Pull `tokens` / `per_token_ms` out of a 200 `:generate` answer. A
/// body this client can't parse is counted as zero tokens rather than
/// failing the run — the request-level `ok` count already recorded the
/// server's verdict.
fn absorb_generate_body(body: &str, acc: &mut GenTally) {
    let Ok(v) = json::parse(body) else { return };
    if let Ok(toks) = v.get("tokens").and_then(|t| t.as_arr()) {
        acc.tokens += toks.len();
    }
    if let Ok(ms) = v.get("per_token_ms").and_then(|t| t.as_arr()) {
        for m in ms {
            if let Ok(x) = m.as_f64() {
                acc.per_token_ms.push(x);
            }
        }
    }
}

/// Deterministic token-id prompt for decode request `i` (varies by
/// index so KV caches do not all replay the same prefix).
fn gen_body_for(
    i: usize,
    prompt_len: usize,
    max_new: usize,
    vocab: usize,
) -> String {
    let vocab = vocab.max(1);
    let toks: Vec<json::Value> = (0..prompt_len)
        .map(|j| json::num(((i * 7 + j * 3) % vocab) as f64))
        .collect();
    json::obj(vec![
        ("tokens", json::arr(toks)),
        ("max_new_tokens", json::num(max_new as f64)),
    ])
    .to_string()
}

/// Deterministic per-request example (varies by index so batches are
/// not degenerate).
fn body_for(i: usize, in_elems: usize) -> String {
    let v = (i % 13) as f64 * 0.125;
    let data: Vec<json::Value> = (0..in_elems)
        .map(|j| json::num(v + (j % 7) as f64 * 0.03125))
        .collect();
    json::obj(vec![("data", json::arr(data))]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bodies_are_valid_json_and_deterministic() {
        let b = body_for(3, 8);
        assert_eq!(b, body_for(3, 8));
        let v = json::parse(&b).unwrap();
        assert_eq!(v.get("data").unwrap().as_arr().unwrap().len(), 8);
    }

    #[test]
    fn report_merging_preserves_class_sums_and_quantiles() {
        let t1 = Tally {
            sent: 3,
            ok: 2,
            server_errors: 1,
            shed: 1,
            latencies_ms: vec![1.0, 3.0],
            ..Tally::default()
        };
        let t2 = Tally {
            sent: 2,
            ok: 1,
            throttled: 1,
            latencies_ms: vec![5.0],
            ..Tally::default()
        };
        let ts = [t1, t2];
        let merged = report_from(ts.iter(), 2.0);
        assert_eq!(merged.sent, 5);
        assert_eq!(merged.ok, 3);
        assert_eq!(merged.shed, 1);
        assert!(merged.shed <= merged.server_errors);
        assert_eq!(merged.max_ms, 5.0);
        assert!((merged.qps - 1.5).abs() < 1e-9);
        // Round-robin shard 0 of 2 sees only t1.
        let w0 = report_from(ts.iter().step_by(2), 2.0);
        assert_eq!(w0.sent, 3);
        assert_eq!(w0.max_ms, 3.0);
        let j = merged.to_json().to_string();
        assert!(j.contains("\"shed_503\""));
        assert!(j.contains("\"qps\""));
    }

    #[test]
    fn sharded_worker_count_is_validated() {
        let spec = LoadSpec {
            addr: "127.0.0.1:1".into(),
            model: "x".into(),
            in_elems: 4,
            requests: 1,
            concurrency: 2,
            target_qps: 0.0,
            retries: 0,
        };
        assert!(run_sharded(&spec, 0).is_err());
        assert!(run_sharded(&spec, 3).is_err());
    }

    #[test]
    fn generate_bodies_are_deterministic_and_in_vocab() {
        let b = gen_body_for(5, 6, 4, 32);
        assert_eq!(b, gen_body_for(5, 6, 4, 32));
        let v = json::parse(&b).unwrap();
        let toks = v.get("tokens").unwrap().as_arr().unwrap();
        assert_eq!(toks.len(), 6);
        for t in toks {
            let t = t.as_f64().unwrap();
            assert!((0.0..32.0).contains(&t) && t.fract() == 0.0);
        }
        assert_eq!(
            v.get("max_new_tokens").unwrap().as_f64().unwrap(),
            4.0
        );
        // Different request index -> different prompt.
        assert_ne!(b, gen_body_for(6, 6, 4, 32));
    }

    #[test]
    fn generate_answers_fold_into_the_decode_tally() {
        let mut acc = GenTally::default();
        absorb_generate_body(
            r#"{"tokens": [1, 2, 3], "per_token_ms": [0.5, 0.25, 0.125]}"#,
            &mut acc,
        );
        absorb_generate_body("not json at all", &mut acc);
        assert_eq!(acc.tokens, 3);
        assert_eq!(acc.per_token_ms, vec![0.5, 0.25, 0.125]);
    }

    #[test]
    fn empty_generate_spec_is_rejected() {
        let spec = GenSpec {
            addr: "127.0.0.1:1".into(),
            model: "x".into(),
            prompt_len: 0,
            max_new: 4,
            vocab: 32,
            requests: 1,
            concurrency: 1,
        };
        assert!(run_generate(&spec).is_err());
        let broken = GenSpec {
            max_new: 0,
            prompt_len: 3,
            ..spec
        };
        assert!(run_generate(&broken).is_err());
    }

    #[test]
    fn empty_spec_is_rejected() {
        let spec = LoadSpec {
            addr: "127.0.0.1:1".into(),
            model: "x".into(),
            in_elems: 0,
            requests: 1,
            concurrency: 1,
            target_qps: 0.0,
            retries: 0,
        };
        assert!(run(&spec).is_err());
    }
}
