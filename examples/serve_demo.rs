//! Serving demo: the L3 coordinator under load — artifact-free.
//!
//! Starts the router with two graph workers (BERT + DLRM archetypes)
//! under a mixed per-layer numeric plan — FLOAT32 first/last layers,
//! ABFP interior at gain 4 (the paper-shaped deployment) — drives an
//! open-loop request stream from multiple client threads, and reports
//! throughput and latency percentiles. Everything runs on a fresh
//! checkout: the graphs are built by deterministic seeded builders and
//! executed by the pure-Rust `GraphExecutor`, so no `make artifacts`
//! step is needed.
//!
//!   cargo run --release --example serve_demo

use std::sync::Arc;
use std::time::Instant;

use abfp::abfp::DeviceConfig;
use abfp::backend::BackendKind;
use abfp::coordinator::{BatchPolicy, Router};
use abfp::data::dataset_for;
use abfp::graph::{GraphPlan, LayerPlan};
use abfp::rng::Pcg64;

fn main() -> anyhow::Result<()> {
    let models = vec!["bert".to_string(), "dlrm".to_string()];
    // FLOAT32 edges, ABFP interior (tile 128, gain 4) — the per-layer
    // freedom that used to take a recompiled artifact is one value here.
    let plan = GraphPlan::edges_float32(LayerPlan::new(
        BackendKind::Abfp,
        DeviceConfig::new(128, (8, 8, 8), 4.0, 0.5),
    ));
    println!("starting graph router: models {models:?}");
    println!("  plan: {}", plan.summary());
    let router = Arc::new(Router::start_graph(
        &models,
        &plan,
        BatchPolicy::new(32, 4)?,
        1024,
        0x5eed,
        0,
    )?);
    for m in router.served_models() {
        println!("  {m}: {}", router.model_meta(&m)?.to_string());
    }

    const CLIENTS: usize = 4;
    const REQS_PER_CLIENT: usize = 64;
    let t0 = Instant::now();
    let mut joins = Vec::new();
    for c in 0..CLIENTS {
        let router = router.clone();
        let models = models.clone();
        joins.push(std::thread::spawn(move || -> anyhow::Result<u64> {
            let mut rng = Pcg64::seeded(100 + c as u64);
            let mut done = 0u64;
            for i in 0..REQS_PER_CLIENT {
                let model = &models[(c + i) % models.len()];
                let ds = dataset_for(model)?;
                let b = ds.batch(&mut rng, 1);
                let shape: Vec<usize> = b.x.shape()[1..].to_vec();
                let x = b.x.clone().reshape(&shape)?;
                let resp = router.infer(model, x)?;
                assert!(!resp.outputs.is_empty());
                done += 1;
            }
            Ok(done)
        }));
    }
    let total: u64 = joins
        .into_iter()
        .map(|j| j.join().unwrap().unwrap())
        .sum();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\n{total} requests from {CLIENTS} clients in {wall:.2}s = {:.1} req/s",
        total as f64 / wall
    );
    for m in router.served_models() {
        let s = router.stats(&m)?;
        println!(
            "  {m:<5} reqs {:>4}  batches {:>3} (mean size {:>4.1})  \
             exec {:>6.1} ms  p50 {:>6.1} ms  p95 {:>6.1} ms",
            s.requests, s.batches, s.mean_batch, s.mean_exec_ms, s.p50_ms, s.p95_ms
        );
    }
    println!("\nNote: requests are single examples; the dynamic batcher\nfuses them into one device execution (dynamic batching win).");
    Ok(())
}
