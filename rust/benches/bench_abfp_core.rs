//! L3 hot path: the Rust ABFP device simulator matmul.
//!
//! This is the substrate under Fig. S1 / Appendix A; the perf pass in
//! EXPERIMENTS.md §Perf tracks the 128-tile case (the paper's preferred
//! device geometry).

use abfp::abfp::{Device, DeviceConfig};
use abfp::benchkit::{black_box, Bench};
use abfp::numerics::bf16_round;
use abfp::parallel;
use abfp::rng::Pcg64;
use abfp::tensor::Tensor;

fn rand_t(rng: &mut Pcg64, shape: &[usize]) -> Tensor {
    let len = shape.iter().product();
    Tensor::new(shape, (0..len).map(|_| bf16_round(rng.normal())).collect()).unwrap()
}

fn main() {
    let mut rng = Pcg64::seeded(1);
    let x = rand_t(&mut rng, &[64, 768]);
    let w = rand_t(&mut rng, &[256, 768]);
    let macs = (64 * 768 * 256) as f64;

    let mut b = Bench::new("abfp_core").with_samples(2, 8);
    for tile in [8usize, 32, 128] {
        let cfg = DeviceConfig::new(tile, (8, 8, 8), 8.0, 0.5);
        let r = b
            .run(&format!("simulator_matmul_t{tile}"), 1, || {
                let mut dev = Device::new(cfg, 7);
                black_box(dev.matmul(&x, &w).unwrap());
            })
            .clone();
        println!(
            "    -> {:.2} GMAC/s (64x768 @ 256x768)",
            r.throughput(macs) / 1e9
        );
    }

    // Staged-weight reuse vs per-call staging: the serving hot path
    // stages once at worker startup, so the delta here is pure win
    // (O(rows*K) quantization + bf16 rounding skipped per call).
    let cfg = DeviceConfig::new(128, (8, 8, 8), 8.0, 0.5);
    let staged = Device::new(cfg, 7).stage_weights(&w).unwrap();
    let r_reuse = b
        .run("matmul_staged_reuse_t128", 1, || {
            let mut dev = Device::new(cfg, 7);
            black_box(dev.matmul_staged(&x, &staged).unwrap());
        })
        .clone();
    let r_restage = b
        .run("matmul_restage_per_call_t128", 1, || {
            let mut dev = Device::new(cfg, 7);
            black_box(dev.matmul(&x, &w).unwrap());
        })
        .clone();
    println!(
        "    -> staged reuse speedup over per-call staging: {:.2}x",
        r_restage.median_ns / r_reuse.median_ns
    );

    // Multi-thread scaling at the paper's preferred tile (same cfg +
    // staged weights as the reuse case above). Coordinate-keyed ADC
    // noise makes every schedule bit-exact (the invariant is pinned by
    // tests/determinism.rs), so the thread count is a pure throughput
    // knob — the speedup here is the tentpole number for the parallel
    // execution engine.
    let mut thread_cases = vec![1usize, 2, 4, parallel::available()];
    thread_cases.sort_unstable();
    thread_cases.dedup();
    let mut medians = Vec::new();
    for &threads in &thread_cases {
        let r = b
            .run(&format!("matmul_staged_t128_threads{threads}"), 1, || {
                let mut dev = Device::new(cfg, 7);
                dev.set_threads(threads);
                black_box(dev.matmul_staged(&x, &staged).unwrap());
            })
            .clone();
        medians.push((threads, r.median_ns));
    }
    let single = medians[0].1;
    for &(threads, median) in &medians[1..] {
        println!(
            "    -> {threads} threads: {:.2}x over single-thread",
            single / median
        );
    }

    // The FLOAT32 reference for the simulator's overhead factor.
    b.run("float32_matmul", 1, || {
        black_box(x.matmul_nt(&w).unwrap());
    });

    // Noiseless variant isolates the RNG cost in the ADC model.
    let cfg = DeviceConfig::new(128, (8, 8, 8), 8.0, 0.0);
    b.run("simulator_matmul_t128_noiseless", 1, || {
        let mut dev = Device::new(cfg, 7);
        black_box(dev.matmul(&x, &w).unwrap());
    });
}
