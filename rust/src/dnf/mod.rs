//! Differential Noise Finetuning (paper section IV-B, Fig. 3).
//!
//! Rust owns the DNF machinery end to end:
//!
//! 1. **Calibrate**: run the `<model>_calib_t<n>` artifact once on one
//!    batch; it returns, per weight-bearing layer, the differential noise
//!    `dy^l = abfp_layer(x^l) - f32_layer(x^l)` with both layers fed the
//!    *same* FLOAT32 input.
//! 2. **Model**: build one 100-bin histogram per layer, smoothed by
//!    adding 0.5 to every bin (the paper's footnote 3), and normalize it
//!    into a sampling distribution (alias method for O(1) draws).
//! 3. **Sample**: during finetuning, draw a noise tensor `xi^l` per tap
//!    and feed it into the `<model>_train_dnf` artifact (Eq. 9).
//!
//! The per-layer statistics (mean / std of `dy^l`) are exactly what
//! Fig. 5 plots; [`LayerNoise`] carries them.

mod alias;
mod histogram;

pub use alias::AliasSampler;
pub use histogram::NoiseHistogram;

use anyhow::Result;

use crate::backend::NumericBackend;
use crate::models;
use crate::rng::Pcg64;
use crate::runtime::{lit_f32, lit_key, lit_scalars, to_tensor, Engine};
use crate::stats::Running;
use crate::tensor::Tensor;

/// The paper's histogram resolution (section V-B).
pub const BINS: usize = 100;
/// The paper's smoothing constant (footnote 3).
pub const SMOOTH: f64 = 0.5;

/// Differential-noise statistics of one layer (the Fig. 5 quantity).
#[derive(Debug, Clone)]
pub struct LayerNoise {
    pub name: String,
    pub shape: Vec<usize>,
    pub mean: f64,
    pub std: f64,
    pub hist: NoiseHistogram,
}

/// Per-layer noise model for one (model, device-config) pair.
#[derive(Debug, Clone)]
pub struct NoiseModel {
    pub model: String,
    pub layers: Vec<LayerNoise>,
}

/// Run the calibration artifact once and build the per-layer noise model.
///
/// `gain`, `bits`, `noise_lsb` select the simulated device; `seed` both
/// the device noise and the calibration batch are derived from it.
pub fn calibrate(
    engine: &Engine,
    model: &str,
    params: &[Tensor],
    batch_x: &Tensor,
    gain: f32,
    bits: (u32, u32, u32),
    noise_lsb: f32,
    seed: u64,
) -> Result<NoiseModel> {
    let tile = engine.manifest.finetune_tile;
    let exe = engine.executable(&models::art_calib(model, tile))?;
    let mut args: Vec<xla::Literal> =
        params.iter().map(lit_f32).collect::<Result<_>>()?;
    args.push(lit_f32(batch_x)?);
    args.push(lit_key(seed));
    args.push(lit_scalars(gain, bits.0, bits.1, bits.2));
    args.push(xla::Literal::scalar(noise_lsb));
    let outs = exe.run(&args)?;

    let info = engine.manifest.model(model)?;
    let mut layers = Vec::with_capacity(outs.len());
    for (i, out) in outs.iter().enumerate() {
        let diff = to_tensor(out)?;
        let name = info
            .taps
            .get(i)
            .map(|t| t.name.clone())
            .unwrap_or_else(|| format!("tap{i}"));
        layers.push(layer_noise(name, &diff));
    }
    Ok(NoiseModel {
        model: model.to_string(),
        layers,
    })
}

/// Host-side calibration of a single matmul layer against any numeric
/// backend: `dy = backend(x, w) - float32(x, w)` with both paths fed
/// the same FLOAT32 input — Eq. 8's differential noise, computed by the
/// Rust simulators instead of the calib artifact. This is how the DNF
/// noise model is built for backends that have no AOT calibration
/// artifact (fixed, bfp), and how the Fig. 5 tile-8 column is produced.
pub fn calibrate_matmul(
    backend: &mut dyn NumericBackend,
    name: &str,
    x: &Tensor,
    w: &Tensor,
) -> Result<LayerNoise> {
    let staged = backend.stage_weights(w)?;
    let y = backend.matmul(x, &staged)?;
    let f = x.matmul_nt(w)?;
    let diff = y.zip(&f, |a, b| a - b)?;
    Ok(layer_noise(name.to_string(), &diff))
}

/// Build one layer's noise description from its differential samples.
pub fn layer_noise(name: String, diff: &Tensor) -> LayerNoise {
    let mut run = Running::new();
    for &v in diff.data() {
        run.push(v as f64);
    }
    let hist = NoiseHistogram::fit(diff.data(), BINS, SMOOTH);
    LayerNoise {
        name,
        shape: diff.shape().to_vec(),
        mean: run.mean(),
        std: run.std(),
        hist,
    }
}

impl NoiseModel {
    /// Sample one xi tensor per tap, shaped for the train batch.
    ///
    /// `scale` multiplies sampled noise (1.0 = the paper's DNF; other
    /// values support the ablation benches).
    pub fn sample_taps(
        &self,
        tap_shapes: &[Vec<usize>],
        rng: &mut Pcg64,
        scale: f32,
        only_layers: Option<&[String]>,
    ) -> Vec<Tensor> {
        self.layers
            .iter()
            .zip(tap_shapes)
            .map(|(layer, shape)| {
                let len: usize = shape.iter().product();
                let active = only_layers
                    .map(|names| names.iter().any(|n| n == &layer.name))
                    .unwrap_or(true);
                if !active || scale == 0.0 {
                    return Tensor::zeros(shape);
                }
                let sampler = AliasSampler::new(&layer.hist.probs())
                    .expect("smoothed histogram probabilities are positive");
                let mut data = Vec::with_capacity(len);
                for _ in 0..len {
                    let bin = sampler.sample(rng);
                    data.push(layer.hist.sample_in_bin(bin, rng) * scale);
                }
                Tensor::new(shape, data).unwrap()
            })
            .collect()
    }

    /// Layer names ranked by descending noise std (the paper selects the
    /// highest-variance layers of SSD for targeted DNF).
    pub fn layers_by_std(&self) -> Vec<(String, f64)> {
        let mut v: Vec<(String, f64)> = self
            .layers
            .iter()
            .map(|l| (l.name.clone(), l.std))
            .collect();
        v.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_noise_stats() {
        let diff = Tensor::from_vec(vec![0.0, 1.0, -1.0, 0.5, -0.5]);
        let ln = layer_noise("l0".into(), &diff);
        assert!(ln.mean.abs() < 1e-9);
        assert!(ln.std > 0.5 && ln.std < 1.0);
        assert_eq!(ln.hist.bins(), BINS);
    }

    #[test]
    fn sampling_matches_source_distribution() {
        // Fit on a bimodal sample; sampled moments must track source.
        let mut rng = Pcg64::seeded(3);
        let mut src = Vec::new();
        for _ in 0..5000 {
            src.push(rng.normal() * 0.1 + if rng.next_f32() < 0.5 { -1.0 } else { 1.0 });
        }
        let t = Tensor::from_vec(src.clone());
        let model = NoiseModel {
            model: "test".into(),
            layers: vec![layer_noise("l0".into(), &t)],
        };
        let shapes = vec![vec![20_000usize]];
        let out = &model.sample_taps(&shapes, &mut rng, 1.0, None)[0];
        let mean = out.mean();
        let var: f64 = out
            .data()
            .iter()
            .map(|&v| (v as f64 - mean).powi(2))
            .sum::<f64>()
            / out.len() as f64;
        let src_mean: f64 = src.iter().map(|&v| v as f64).sum::<f64>() / src.len() as f64;
        let src_var: f64 = src
            .iter()
            .map(|&v| (v as f64 - src_mean).powi(2))
            .sum::<f64>()
            / src.len() as f64;
        assert!((mean - src_mean).abs() < 0.05, "{mean} vs {src_mean}");
        assert!((var - src_var).abs() / src_var < 0.1, "{var} vs {src_var}");
    }

    #[test]
    fn selective_layers_zero_inactive() {
        let t = Tensor::from_vec(vec![1.0; 100]);
        let model = NoiseModel {
            model: "test".into(),
            layers: vec![
                layer_noise("a".into(), &t),
                layer_noise("b".into(), &t),
            ],
        };
        let shapes = vec![vec![8usize], vec![8usize]];
        let mut rng = Pcg64::seeded(4);
        let only = vec!["b".to_string()];
        let xs = model.sample_taps(&shapes, &mut rng, 1.0, Some(&only));
        assert!(xs[0].data().iter().all(|&v| v == 0.0));
        assert!(xs[1].data().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn host_calibration_tracks_backend_error() {
        use crate::abfp::DeviceConfig;
        use crate::backend::BackendKind;

        let mut rng = Pcg64::seeded(0xca11b);
        let x = Tensor::new(&[16, 64], rng.normal_vec(16 * 64)).unwrap();
        let w = Tensor::new(
            &[8, 64],
            (0..8 * 64).map(|_| rng.laplace()).collect(),
        )
        .unwrap();
        let cfg = DeviceConfig::new(32, (8, 8, 8), 2.0, 0.5);

        // The exact backend produces a zero noise model...
        let mut f32b = BackendKind::Float32.build(cfg, 1);
        let ln = calibrate_matmul(f32b.as_mut(), "fc", &x, &w).unwrap();
        assert_eq!(ln.std, 0.0);
        assert_eq!(ln.name, "fc");

        // ...the device backends a non-trivial, samplable one.
        let mut abfp = BackendKind::Abfp.build(cfg, 1);
        let ln = calibrate_matmul(abfp.as_mut(), "fc", &x, &w).unwrap();
        assert!(ln.std > 0.0);
        assert_eq!(ln.hist.bins(), BINS);
        let model = NoiseModel {
            model: "test".into(),
            layers: vec![ln],
        };
        let xs = model.sample_taps(&[vec![64usize]], &mut rng, 1.0, None);
        assert!(xs[0].data().iter().any(|&v| v != 0.0));
    }

    #[test]
    fn ranking_by_std() {
        let small = Tensor::from_vec(vec![0.01, -0.01, 0.02, -0.02]);
        let big = Tensor::from_vec(vec![1.0, -1.0, 2.0, -2.0]);
        let model = NoiseModel {
            model: "test".into(),
            layers: vec![
                layer_noise("small".into(), &small),
                layer_noise("big".into(), &big),
            ],
        };
        let ranked = model.layers_by_std();
        assert_eq!(ranked[0].0, "big");
    }
}
