//! Fig. S1 regeneration cost: one error-distribution cell (the paper's
//! protocol matmul) on the Rust simulator, per tile width.

use abfp::abfp::{matmul_error_stats, DeviceConfig};
use abfp::benchkit::{black_box, Bench};
use abfp::sweep::figs1::protocol_inputs;

fn main() {
    let (x, w) = protocol_inputs(2022, 100);
    let mut b = Bench::new("figs1_cell").with_samples(1, 5);
    for tile in [8usize, 32, 128] {
        let cfg = DeviceConfig::new(tile, (8, 8, 8), 8.0, 0.5);
        b.run(&format!("error_stats_t{tile}_100x768"), 1, || {
            black_box(matmul_error_stats(cfg, 7, &x, &w).unwrap());
        });
    }
}
