//! The HTTP/1.1 front door: a dependency-free (`std::net` + vendored
//! `netpoll`) server that exposes the in-process [`Router`] to the
//! network — the MLPerf datacenter-inference "server scenario"
//! boundary.
//!
//! ## The readiness event loop
//!
//! Connections are **state machines, not threads**. A small fixed pool
//! of event-loop threads ([`HttpConfig::pool`], default 4) shares one
//! nonblocking listener; each loop multiplexes its connections over
//! `poll(2)` readiness (vendored `netpoll` — the crate root forbids
//! unsafe, so the one syscall lives there). Reading a request, waiting
//! on a worker, or flushing a response parks *state*, never a thread:
//! 1024 idle keep-alive connections cost memory and fds, not threads,
//! and a slow-loris client is reaped by [`HttpConfig::conn_deadline`]
//! without ever occupying one.
//!
//! A predict submits through [`Router::try_submit_notify`] with a UDP
//! waker hook: the worker pokes the loop's waker socket right after the
//! response lands on the oneshot channel, so loops sleep in `poll`
//! instead of spinning on `try_recv`. Per connection the machine is:
//!
//! ```text
//!        read         head+body        try_submit_notify
//!   Idle ----> ReadHead ----> ReadBody ----> InFlight --(waker)--+
//!    ^  (100-continue appended while the body streams)           |
//!    |                                                           v
//!    +------------------- keep-alive / pipelining <---------- Write
//!                    (`connection: close` / protocol error -> Linger)
//! ```
//!
//! Routes:
//!
//! * `POST /v1/models/{model}:predict` — JSON body
//!   `{"data": [...], "shape": [...]?}` (one example; `shape` defaults
//!   to flat). 200 answers carry per-example `outputs`, `queue_ms`,
//!   `total_ms`, `batch_size`.
//! * `POST /v1/models/{model}:generate` — JSON body
//!   `{"tokens": [...], "max_new_tokens": N}` (a token-id prompt).
//!   Drives the worker's KV-cache autoregressive decode loop; 200
//!   answers carry the decoded `tokens`, `per_token_ms` (entry 0 is
//!   prompt prefill + first token), `tok_p50_ms`/`tok_p95_ms`,
//!   `cache_len`/`cached_elems`, and the usual timing fields. Models
//!   without decode support answer 400.
//! * `GET /v1/models` — the served-model roster (`models`, a name
//!   array) plus per-model executor metadata (`detail`: executor kind,
//!   shapes, the worker's `batching` mode; graph workers add layer
//!   count and the per-layer numeric plan).
//! * `GET /healthz` — readiness: `ok` when every breaker is Closed,
//!   `degraded: <models>` (still 200 — traffic is served on the
//!   fallback) when one is Open/HalfOpen, 503 `restarting` when no
//!   model can serve, 503 `draining` during graceful shutdown.
//! * `GET /metrics` — Prometheus text format from [`ServerStats`] +
//!   [`HttpStats`] (queue depth, batch-size histogram, deadline sheds,
//!   wakeups).
//!
//! Error-status contract (pinned by `tests/http.rs`):
//!
//! | condition                               | status |
//! |-----------------------------------------|--------|
//! | malformed HTTP / bad JSON / bad shape   | 400    |
//! | unknown model or route                  | 404    |
//! | unsupported method / transfer encoding  | 405 / 400 |
//! | idle / trickled request past the deadline | close / 408 |
//! | body over [`MAX_BODY`]                  | 413    |
//! | worker queue full ([`SubmitError::Busy`]) | 429 (+ `retry-after: 1`) |
//! | executor failure / worker dropped       | 500    |
//! | worker gone / shed past service deadline | 503   |
//! | device fault / guard trip / mid-restart ([`RequestError::Unavailable`]) | 503 (+ `retry-after: 1`) |
//!
//! Backpressure: the loop submits through the nonblocking
//! [`Router::try_submit_notify`], so a saturated model queue answers
//! 429 immediately — no loop thread ever parks behind a slow model.
//! Keep-alive and pipelining are honoured (HTTP/1.1 default;
//! `connection: close` respected); graceful [`HttpServer::shutdown`]
//! stops accepting, completes every in-flight request, flushes, and
//! closes — bounded by a drain grace period.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, UdpSocket};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};
use netpoll::{Poller, READABLE, WRITABLE};

use super::server::{
    HealthSnapshot, Notify, RequestError, Response, Router, ServerStats, SubmitError,
};
use crate::json;
use crate::stats::quantile_sorted;
use crate::tensor::Tensor;

/// Header-section cap (request line + headers).
const MAX_HEAD: usize = 64 * 1024;
/// Request-body cap (a 1M-element f32 example in JSON is ~12 MB).
pub const MAX_BODY: usize = 64 * 1024 * 1024;
/// Post-error drain window: after a protocol-error response the write
/// side half-closes and the read side discards the rest of the upload
/// for at most this long, so close-with-unread-data RST can't destroy
/// the error response before the client reads it.
const LINGER: Duration = Duration::from_millis(500);

const CT_JSON: &str = "application/json";
const CT_TEXT: &str = "text/plain; charset=utf-8";
/// Prometheus exposition format version.
const CT_PROM: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Event-loop tuning. [`HttpConfig::default`] is what
/// [`HttpServer::bind`] uses; [`HttpServer::bind_with`] takes an
/// explicit one (the soak tests shorten `conn_deadline` to reap
/// slow-loris clients fast).
#[derive(Debug, Clone, Copy)]
pub struct HttpConfig {
    /// Event-loop threads sharing the listener. The server's whole
    /// thread budget is `pool` + one worker per model — independent of
    /// connection count.
    pub pool: usize,
    /// Per-request read deadline: a keep-alive connection may sit idle
    /// (closed quietly) or trickle a partial request (408) for at most
    /// this long.
    pub conn_deadline: Duration,
    /// A client that stops reading (full kernel send buffer, zero write
    /// progress for this long) is dropped instead of parking its
    /// response forever.
    pub write_stall: Duration,
    /// Per-loop connection cap; accepts pause (backlog holds) above it.
    pub max_conns: usize,
    /// Graceful-shutdown drain bound: in-flight requests get this long
    /// to complete and flush before the loop force-closes.
    pub shutdown_grace: Duration,
}

impl Default for HttpConfig {
    fn default() -> HttpConfig {
        HttpConfig {
            pool: 4,
            conn_deadline: Duration::from_secs(60),
            write_stall: Duration::from_secs(5),
            max_conns: 16 * 1024,
            shutdown_grace: Duration::from_secs(10),
        }
    }
}

/// Front-door counters (atomic; shared by every loop thread), exposed
/// through `GET /metrics` alongside the per-model [`ServerStats`].
#[derive(Debug, Default)]
pub struct HttpStats {
    wakeups: AtomicU64,
    accepted: AtomicU64,
    open: AtomicU64,
    reaped: AtomicU64,
}

impl HttpStats {
    /// Event-loop `poll` returns across the pool.
    pub fn wakeups(&self) -> u64 {
        self.wakeups.load(Ordering::Relaxed)
    }

    /// Connections accepted since startup.
    pub fn accepted(&self) -> u64 {
        self.accepted.load(Ordering::Relaxed)
    }

    /// Connections open right now (gauge).
    pub fn open(&self) -> u64 {
        self.open.load(Ordering::Relaxed)
    }

    /// Connections force-closed by deadline / write stall (slow-loris
    /// and stopped-reader reaping).
    pub fn reaped(&self) -> u64 {
        self.reaped.load(Ordering::Relaxed)
    }
}

/// The listening server. Dropping it (or calling
/// [`HttpServer::shutdown`]) stops accepting, drains in-flight
/// requests, joins the loop pool, and releases the port.
pub struct HttpServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    loops: Vec<JoinHandle<()>>,
    /// Each loop's waker address — nudged at shutdown so loops notice
    /// the flag mid-`poll` instead of at the next timeout.
    wakers: Vec<SocketAddr>,
    stats: Arc<HttpStats>,
}

impl HttpServer {
    /// Bind and start serving `router` on `addr` (e.g. `"0.0.0.0:8080"`;
    /// port 0 picks an ephemeral port — read it back with
    /// [`HttpServer::addr`]) under the default [`HttpConfig`].
    pub fn bind(router: Arc<Router>, addr: &str) -> Result<HttpServer> {
        HttpServer::bind_with(router, addr, HttpConfig::default())
    }

    /// [`HttpServer::bind`] with explicit event-loop tuning.
    pub fn bind_with(
        router: Arc<Router>,
        addr: &str,
        cfg: HttpConfig,
    ) -> Result<HttpServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(HttpStats::default());
        let pool = cfg.pool.max(1);
        let mut loops = Vec::with_capacity(pool);
        let mut wakers = Vec::with_capacity(pool);
        for i in 0..pool {
            let listener = listener.try_clone()?;
            // Loopback UDP waker pair: the loop polls `rx`; workers poke
            // through the connected `tx` (see `UdpNotify`).
            let waker_rx = UdpSocket::bind("127.0.0.1:0")?;
            waker_rx.set_nonblocking(true)?;
            let waker_tx = UdpSocket::bind("127.0.0.1:0")?;
            waker_tx.connect(waker_rx.local_addr()?)?;
            wakers.push(waker_rx.local_addr()?);
            let notify: Arc<dyn Notify> = Arc::new(UdpNotify(waker_tx));
            let (r, sd, st) = (router.clone(), shutdown.clone(), stats.clone());
            loops.push(
                std::thread::Builder::new()
                    .name(format!("abfp-http-loop-{i}"))
                    .spawn(move || event_loop(listener, waker_rx, notify, r, st, sd, cfg))?,
            );
        }
        Ok(HttpServer {
            addr: local,
            shutdown,
            loops,
            wakers,
            stats,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Front-door counters (wakeups, connections).
    pub fn stats(&self) -> Arc<HttpStats> {
        self.stats.clone()
    }

    /// Graceful shutdown: stop accepting, let in-flight requests finish
    /// and flush (bounded by [`HttpConfig::shutdown_grace`]), join the
    /// loop pool. Idempotent.
    pub fn shutdown(&mut self) {
        if !self.shutdown.swap(true, Ordering::SeqCst) {
            // Nudge every loop out of a long idle `poll`.
            if let Ok(nudge) = UdpSocket::bind("127.0.0.1:0") {
                for w in &self.wakers {
                    nudge.send_to(&[1], w).ok();
                }
            }
        }
        for j in self.loops.drain(..) {
            j.join().ok();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The worker-side wakeup hook: a connected loopback UDP socket whose
/// datagrams make the owning loop's `poll` return. Payload is
/// irrelevant — readability is the doorbell.
struct UdpNotify(UdpSocket);

impl Notify for UdpNotify {
    fn notify(&self) {
        self.0.send(&[1]).ok();
    }
}

/// One event-loop thread: accept + per-connection state machines over a
/// rebuilt-per-iteration `poll(2)` set (level-triggered, allocation-free
/// once warm).
fn event_loop(
    listener: TcpListener,
    waker: UdpSocket,
    notify: Arc<dyn Notify>,
    router: Arc<Router>,
    stats: Arc<HttpStats>,
    shutdown: Arc<AtomicBool>,
    cfg: HttpConfig,
) {
    let mut poller = Poller::new();
    let mut conns: Vec<Conn> = Vec::new();
    let mut accept_backoff: Option<Instant> = None;
    let mut drain_deadline: Option<Instant> = None;
    loop {
        let stopping = shutdown.load(Ordering::SeqCst);
        let now = Instant::now();
        if stopping {
            let dd = *drain_deadline.get_or_insert(now + cfg.shutdown_grace);
            if conns.is_empty() || now >= dd {
                stats.open.fetch_sub(conns.len() as u64, Ordering::Relaxed);
                return; // drained (or grace expired: force-close)
            }
        }

        poller.clear();
        let accepting = !stopping
            && conns.len() < cfg.max_conns
            && !accept_backoff.is_some_and(|until| now < until);
        let lslot = if accepting {
            Some(poller.register(&listener, READABLE))
        } else {
            None
        };
        let wslot = poller.register(&waker, READABLE);
        for conn in conns.iter_mut() {
            conn.slot = poller.register(&conn.stream, conn.interest());
        }

        // Waiting on a worker is waker-driven, but keep a short
        // fallback tick so a lost datagram degrades to latency, not a
        // hang; deadlines only need coarse ticks.
        let any_pending = conns.iter().any(|c| c.pending.is_some());
        let timeout = if any_pending || stopping {
            Duration::from_millis(10)
        } else if !conns.is_empty() {
            Duration::from_millis(50)
        } else {
            Duration::from_millis(500)
        };
        if poller.wait(Some(timeout)).is_err() {
            std::thread::sleep(Duration::from_millis(5));
            continue;
        }
        stats.wakeups.fetch_add(1, Ordering::Relaxed);

        if poller.readable(wslot) {
            let mut sink = [0u8; 64];
            while waker.recv(&mut sink).is_ok() {}
        }

        if lslot.is_some_and(|ls| poller.readable(ls)) {
            loop {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nodelay(true).ok();
                        if stream.set_nonblocking(true).is_err() {
                            continue;
                        }
                        stats.accepted.fetch_add(1, Ordering::Relaxed);
                        stats.open.fetch_add(1, Ordering::Relaxed);
                        conns.push(Conn::new(stream));
                        if conns.len() >= cfg.max_conns {
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(_) => {
                        // EMFILE and friends: back off instead of
                        // busy-spinning the loop at 100% CPU.
                        accept_backoff = Some(Instant::now() + Duration::from_millis(20));
                        break;
                    }
                }
            }
        }

        let now = Instant::now();
        let mut i = 0;
        while i < conns.len() {
            let readable = poller.readable(conns[i].slot);
            let writable = poller.writable(conns[i].slot);
            let keep = conns[i].step(
                readable, writable, now, stopping, &router, &stats, &notify, &cfg,
            );
            if keep {
                i += 1;
            } else {
                conns.swap_remove(i);
                stats.open.fetch_sub(1, Ordering::Relaxed);
            }
        }
    }
}

/// One parsed request.
struct HttpRequest {
    method: String,
    path: String,
    keep_alive: bool,
    body: Vec<u8>,
}

/// Head fields cached while the body streams in (the head is scanned
/// and parsed exactly once per request).
struct ParsedHead {
    head_end: usize,
    method: String,
    path: String,
    keep_alive: bool,
    content_length: usize,
    expect_continue: bool,
}

/// A predict or generate in flight on the worker: the oneshot receiver
/// plus what the response writer needs once it lands.
struct Pending {
    rx: Receiver<Result<Response, RequestError>>,
    model: String,
    head_only: bool,
    keep_alive: bool,
    /// Format the answer as a `:generate` decode response.
    generate: bool,
}

/// A protocol-level failure mapped to a status for the client.
struct HttpError {
    status: u16,
    msg: String,
}

impl HttpError {
    fn new(status: u16, msg: impl Into<String>) -> HttpError {
        HttpError {
            status,
            msg: msg.into(),
        }
    }
}

/// One connection's state machine. All I/O is nonblocking; the loop
/// drives `step` off poll readiness.
struct Conn {
    stream: TcpStream,
    /// This iteration's poll slot (stale between registrations; a fresh
    /// conn's `usize::MAX` reads as not-ready, which is safe).
    slot: usize,
    /// Inbound bytes carried across reads (keep-alive pipelining).
    buf: Vec<u8>,
    /// Resumable `\r\n\r\n` scan offset into `buf`.
    scanned: usize,
    parsed: Option<ParsedHead>,
    /// `100 Continue` already sent for the in-progress request.
    continued: bool,
    pending: Option<Pending>,
    /// Outbound bytes not yet accepted by the kernel.
    out: Vec<u8>,
    out_pos: usize,
    /// When the current request phase started (reset per request and
    /// when the write buffer drains) — the conn-deadline clock.
    t0: Instant,
    /// Last write progress (the write-stall clock).
    wrote: Instant,
    peer_eof: bool,
    close_after_flush: bool,
    /// Close with a half-close + read-drain (protocol errors), so the
    /// error response survives the client's remaining upload.
    linger: bool,
    /// Draining mode: write side shut, discarding reads until EOF or
    /// the linger deadline.
    draining: Option<Instant>,
}

impl Conn {
    fn new(stream: TcpStream) -> Conn {
        let now = Instant::now();
        Conn {
            stream,
            slot: usize::MAX,
            buf: Vec::new(),
            scanned: 0,
            parsed: None,
            continued: false,
            pending: None,
            out: Vec::new(),
            out_pos: 0,
            t0: now,
            wrote: now,
            peer_eof: false,
            close_after_flush: false,
            linger: false,
            draining: None,
        }
    }

    /// What this connection needs `poll` to watch for right now.
    fn interest(&self) -> u8 {
        if self.draining.is_some() {
            return READABLE;
        }
        let mut interest = 0;
        // Reads pause while a predict is in flight (response ordering +
        // natural backpressure: the kernel buffers pipelined bytes) and
        // once the inbound buffer holds a max-size request.
        if self.pending.is_none()
            && !self.peer_eof
            && !self.close_after_flush
            && self.buf.len() <= MAX_HEAD + MAX_BODY + 4
        {
            interest |= READABLE;
        }
        if self.out_pos < self.out.len() {
            interest |= WRITABLE;
        }
        interest
    }

    /// Drive the state machine one tick. Returns false when the
    /// connection should be dropped.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &mut self,
        readable: bool,
        writable: bool,
        now: Instant,
        stopping: bool,
        router: &Router,
        http: &HttpStats,
        notify: &Arc<dyn Notify>,
        cfg: &HttpConfig,
    ) -> bool {
        if let Some(deadline) = self.draining {
            return self.drain_tick(readable, now, deadline);
        }

        // 1. A completed predict becomes a response (waker-driven).
        if let Some(p) = &self.pending {
            match p.rx.try_recv() {
                Err(TryRecvError::Empty) => {}
                outcome => {
                    let p = self.pending.take().unwrap();
                    let (status, body) = match outcome {
                        Ok(Ok(resp)) if p.generate => {
                            (200, generate_body(&p.model, &resp))
                        }
                        Ok(Ok(resp)) => (200, response_body(&p.model, &resp)),
                        Ok(Err(e @ RequestError::Exec(_))) => {
                            (500, error_body(&e.to_string()))
                        }
                        Ok(Err(e @ RequestError::DeadlineExceeded { .. })) => {
                            (503, error_body(&e.to_string()))
                        }
                        Ok(Err(e @ RequestError::Unavailable { .. })) => {
                            (503, error_body(&e.to_string()))
                        }
                        Err(_) => (500, error_body("worker dropped the request")),
                    };
                    self.push_response(
                        status,
                        CT_JSON,
                        body.as_bytes(),
                        p.keep_alive,
                        p.head_only,
                    );
                    if !p.keep_alive {
                        self.close_after_flush = true;
                    }
                    self.t0 = now;
                }
            }
        }

        // 2. Pull whatever the socket has (up to the buffer cap).
        if readable && self.interest() & READABLE != 0 {
            let mut chunk = [0u8; 8192];
            loop {
                match self.stream.read(&mut chunk) {
                    Ok(0) => {
                        self.peer_eof = true;
                        break;
                    }
                    Ok(n) => {
                        self.buf.extend_from_slice(&chunk[..n]);
                        if self.buf.len() > MAX_HEAD + MAX_BODY + 4 {
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => return false,
                }
            }
        }

        // 3. Turn buffered bytes into requests (pipelining: keep going
        // until a predict goes in flight or the bytes run dry).
        while self.pending.is_none() && !self.close_after_flush && self.draining.is_none()
        {
            match self.try_extract() {
                Err(e) => {
                    // Protocol error: answer it, then half-close +
                    // drain so the response survives the rest of the
                    // upload.
                    let body = error_body(&e.msg);
                    self.push_response(e.status, CT_JSON, body.as_bytes(), false, false);
                    self.close_after_flush = true;
                    self.linger = true;
                }
                Ok(None) => break,
                Ok(Some(req)) => {
                    self.t0 = now;
                    self.dispatch(req, stopping, router, http, notify);
                }
            }
        }

        // 4. Flush; a dead write side ends the connection.
        if self.flush(now).is_err() {
            return false;
        }
        if self.out_pos >= self.out.len() && !self.out.is_empty() {
            self.out.clear();
            self.out_pos = 0;
            self.t0 = now;
        }
        let _ = writable; // readiness consumed implicitly by flush()

        // 5. Close / reap decisions.
        let flushed = self.out.is_empty();
        if self.close_after_flush && flushed {
            if self.linger {
                self.stream.shutdown(std::net::Shutdown::Write).ok();
                self.draining = Some(now + LINGER);
                return true;
            }
            return false;
        }
        if !flushed && now.duration_since(self.wrote) > cfg.write_stall {
            http.reaped.fetch_add(1, Ordering::Relaxed);
            return false; // client stopped reading
        }
        if self.pending.is_none() && flushed {
            let partial = !self.buf.is_empty() || self.parsed.is_some();
            if self.peer_eof {
                return false; // clean close (any partial tail is void)
            }
            if stopping {
                if partial {
                    // Half-received request at shutdown: answer and go.
                    let body = error_body("server shutting down");
                    self.push_response(503, CT_JSON, body.as_bytes(), false, false);
                    self.close_after_flush = true;
                    self.linger = true;
                    return true;
                }
                return false; // idle at shutdown
            }
            if now.duration_since(self.t0) > cfg.conn_deadline {
                http.reaped.fetch_add(1, Ordering::Relaxed);
                if partial {
                    // Trickled (slow-loris) request: 408 then close.
                    let body = error_body("request timed out");
                    self.push_response(408, CT_JSON, body.as_bytes(), false, false);
                    self.close_after_flush = true;
                    self.linger = true;
                    return true;
                }
                return false; // idle keep-alive: close quietly
            }
        }
        true
    }

    /// Linger mode: discard the client's remaining upload until EOF or
    /// the deadline, then drop.
    fn drain_tick(&mut self, readable: bool, now: Instant, deadline: Instant) -> bool {
        if now >= deadline {
            return false;
        }
        if readable {
            let mut sink = [0u8; 8192];
            loop {
                match self.stream.read(&mut sink) {
                    Ok(0) => return false, // client saw the close
                    Ok(_) => {}            // discard
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(_) => return false,
                }
            }
        }
        true
    }

    /// Try to carve one complete request out of `buf`. `Ok(None)` =
    /// need more bytes (a `100 Continue` may have been queued).
    fn try_extract(&mut self) -> Result<Option<HttpRequest>, HttpError> {
        if self.parsed.is_none() {
            if let Some(head_end) = find_head_end_from(&self.buf, self.scanned) {
                let head = std::str::from_utf8(&self.buf[..head_end])
                    .map_err(|_| HttpError::new(400, "non-UTF-8 request head"))?;
                let (method, path, keep_alive, content_length, expect_continue) =
                    parse_head(head)?;
                if content_length > MAX_BODY {
                    return Err(HttpError::new(
                        413,
                        format!("body of {content_length} bytes exceeds {MAX_BODY}"),
                    ));
                }
                self.parsed = Some(ParsedHead {
                    head_end,
                    method,
                    path,
                    keep_alive,
                    content_length,
                    expect_continue,
                });
            } else if self.buf.len() > MAX_HEAD {
                return Err(HttpError::new(413, "request head too large"));
            } else {
                // Resume the \r\n\r\n search just before the tail (the
                // terminator may straddle a read boundary).
                self.scanned = self.buf.len().saturating_sub(3);
                return Ok(None);
            }
        }
        let p = self.parsed.as_ref().unwrap();
        let total = p.head_end + 4 + p.content_length;
        if self.buf.len() < total {
            // Body still in flight: honour `expect: 100-continue` once
            // so clients like curl start sending it.
            if p.expect_continue && !self.continued {
                self.continued = true;
                self.out.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
            }
            return Ok(None);
        }
        let p = self.parsed.take().unwrap();
        let body = self.buf[p.head_end + 4..total].to_vec();
        self.buf.drain(..total);
        self.scanned = 0;
        self.continued = false;
        Ok(Some(HttpRequest {
            method: p.method,
            path: p.path,
            keep_alive: p.keep_alive,
            body,
        }))
    }

    /// Route one complete request: predicts go in flight on the worker,
    /// everything else is answered synchronously.
    fn dispatch(
        &mut self,
        req: HttpRequest,
        stopping: bool,
        router: &Router,
        http: &HttpStats,
        notify: &Arc<dyn Notify>,
    ) {
        // HEAD gets GET's status and headers (content-length included)
        // with the body elided, per HTTP/1.1 — so a `HEAD /healthz`
        // liveness probe sees the same 200 a GET would.
        let head_only = req.method == "HEAD";
        let keep_alive = req.keep_alive && !stopping;
        let action = |suffix: &'static str| {
            (req.method == "POST")
                .then(|| {
                    req.path
                        .strip_prefix("/v1/models/")
                        .and_then(|rest| rest.strip_suffix(suffix))
                })
                .flatten()
                .filter(|m| !m.is_empty())
        };
        let predict_model = action(":predict");
        let generate_model = action(":generate");
        if predict_model.is_some() || generate_model.is_some() {
            let (model, submitted) = match predict_model {
                Some(model) => (model, start_predict(router, model, &req.body, notify)),
                None => {
                    let model = generate_model.unwrap();
                    (model, start_generate(router, model, &req.body, notify))
                }
            };
            match submitted {
                Ok(rx) => {
                    self.pending = Some(Pending {
                        rx,
                        model: model.to_string(),
                        head_only,
                        keep_alive,
                        generate: generate_model.is_some(),
                    });
                    return;
                }
                Err((status, body)) => {
                    self.push_response(
                        status,
                        CT_JSON,
                        body.as_bytes(),
                        keep_alive,
                        head_only,
                    );
                }
            }
        } else {
            let (status, ctype, body) = route_sync(router, http, &req, stopping);
            self.push_response(status, ctype, body.as_bytes(), keep_alive, head_only);
        }
        if !keep_alive {
            self.close_after_flush = true;
        }
    }

    /// Queue one response (status line + headers + body) for writing.
    fn push_response(
        &mut self,
        status: u16,
        ctype: &str,
        body: &[u8],
        keep_alive: bool,
        head_only: bool,
    ) {
        let conn = if keep_alive { "keep-alive" } else { "close" };
        // Both backpressure (429) and degraded-service (503) answers
        // are retryable: tell well-behaved clients when to come back
        // (loadgen's retry budget honours this).
        let retry = if status == 429 || status == 503 {
            "retry-after: 1\r\n"
        } else {
            ""
        };
        let head = format!(
            "HTTP/1.1 {status} {}\r\ncontent-type: {ctype}\r\ncontent-length: {}\r\nconnection: {conn}\r\n{retry}\r\n",
            reason(status),
            body.len()
        );
        self.out.extend_from_slice(head.as_bytes());
        if !head_only {
            self.out.extend_from_slice(body);
        }
    }

    /// Nonblocking flush of the outbound buffer.
    fn flush(&mut self, now: Instant) -> std::io::Result<()> {
        while self.out_pos < self.out.len() {
            match self.stream.write(&self.out[self.out_pos..]) {
                Ok(0) => return Err(ErrorKind::WriteZero.into()),
                Ok(n) => {
                    self.out_pos += n;
                    self.wrote = now;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
        }
        Ok(())
    }
}

/// [`SubmitError`] -> HTTP status (the typed front-door contract).
fn submit_status(e: &SubmitError) -> u16 {
    match e {
        SubmitError::UnknownModel(_) => 404,
        SubmitError::BadShape(_) => 400,
        SubmitError::Busy(_) => 429,
        SubmitError::Gone(_) => 503,
    }
}

/// Parse + submit a predict; `Err` is an immediate `(status, body)`.
fn start_predict(
    router: &Router,
    model: &str,
    body: &[u8],
    notify: &Arc<dyn Notify>,
) -> Result<Receiver<Result<Response, RequestError>>, (u16, String)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400, error_body("body is not UTF-8")))?;
    let value =
        json::parse(text).map_err(|e| (400, error_body(&format!("invalid JSON: {e}"))))?;
    let x = parse_tensor(&value).map_err(|e| (400, error_body(&e.to_string())))?;
    router
        .try_submit_notify(model, x, Some(notify.clone()))
        .map_err(|e| (submit_status(&e), error_body(&e.to_string())))
}

/// Parse + submit a `:generate`; `Err` is an immediate `(status, body)`.
/// Body contract: `{"tokens": [...], "max_new_tokens": N}`.
fn start_generate(
    router: &Router,
    model: &str,
    body: &[u8],
    notify: &Arc<dyn Notify>,
) -> Result<Receiver<Result<Response, RequestError>>, (u16, String)> {
    let text = std::str::from_utf8(body)
        .map_err(|_| (400, error_body("body is not UTF-8")))?;
    let value =
        json::parse(text).map_err(|e| (400, error_body(&format!("invalid JSON: {e}"))))?;
    let contract = r#"body must be {"tokens": [...], "max_new_tokens": N}"#;
    let prompt: Vec<f32> = value
        .get("tokens")
        .map_err(|_| (400, error_body(contract)))?
        .as_arr()
        .map_err(|e| (400, error_body(&e.to_string())))?
        .iter()
        .map(|n| n.as_f64().map(|f| f as f32))
        .collect::<Result<_>>()
        .map_err(|e| (400, error_body(&e.to_string())))?;
    let max_new = value
        .get("max_new_tokens")
        .map_err(|_| (400, error_body(contract)))?
        .as_f64()
        .map_err(|e| (400, error_body(&e.to_string())))?;
    if !(max_new.is_finite() && max_new >= 0.0) {
        return Err((400, error_body("max_new_tokens must be a non-negative number")));
    }
    router
        .try_submit_generate(model, prompt, max_new as usize, Some(notify.clone()))
        .map_err(|e| (submit_status(&e), error_body(&e.to_string())))
}

/// Dispatch a non-predict request: `(status, content-type, body)`.
/// HEAD routes exactly like GET (the caller elides the body when
/// writing).
fn route_sync(
    router: &Router,
    http: &HttpStats,
    req: &HttpRequest,
    stopping: bool,
) -> (u16, &'static str, String) {
    let method = match req.method.as_str() {
        "HEAD" => "GET",
        m => m,
    };
    match (method, req.path.as_str()) {
        ("GET", "/healthz") => healthz_body(router, stopping),
        ("GET", "/v1/models") => (200, CT_JSON, models_body(router)),
        ("GET", "/metrics") => (200, CT_PROM, metrics_body(router, http)),
        ("POST", _) => (404, CT_JSON, error_body("no such route")),
        ("GET", _) => (404, CT_JSON, error_body("no such route")),
        _ => (405, CT_JSON, error_body("method not allowed")),
    }
}

/// Readiness-aware `/healthz` (it used to be an unconditional static
/// ok): 503 while draining for shutdown or while every model's worker
/// is mid-restart; `degraded` (still 200 — traffic is being served,
/// on the fallback) when any breaker is not Closed; the healthy answer
/// stays byte-identical `ok\n`.
fn healthz_body(router: &Router, stopping: bool) -> (u16, &'static str, String) {
    if stopping {
        return (503, CT_TEXT, "draining\n".to_string());
    }
    if !router.ready() {
        return (503, CT_TEXT, "restarting\n".to_string());
    }
    let degraded = router.degraded_models();
    if degraded.is_empty() {
        (200, CT_TEXT, "ok\n".to_string())
    } else {
        (200, CT_TEXT, format!("degraded: {}\n", degraded.join(",")))
    }
}

/// Request tensor: `{"data": [...], "shape": [...]?}`.
fn parse_tensor(v: &json::Value) -> Result<Tensor> {
    let data_v = v
        .get("data")
        .map_err(|_| anyhow!(r#"body must be {{"data": [...], "shape": [...]?}}"#))?;
    let data: Vec<f32> = data_v
        .as_arr()?
        .iter()
        .map(|n| n.as_f64().map(|f| f as f32))
        .collect::<Result<_>>()?;
    let shape = match v.opt("shape") {
        Some(s) => s.as_shape()?,
        None => vec![data.len()],
    };
    Tensor::new(&shape, data)
}

fn tensor_json(t: &Tensor) -> json::Value {
    json::obj(vec![
        (
            "shape",
            json::arr(t.shape().iter().map(|&d| json::num(d as f64)).collect()),
        ),
        (
            "data",
            json::arr(t.data().iter().map(|&v| json::num(v as f64)).collect()),
        ),
    ])
}

fn response_body(model: &str, r: &Response) -> String {
    json::obj(vec![
        ("model", json::s(model)),
        ("outputs", json::arr(r.outputs.iter().map(tensor_json).collect())),
        ("queue_ms", json::num(r.queue_ms)),
        ("total_ms", json::num(r.total_ms)),
        ("batch_size", json::num(r.batch_size as f64)),
    ])
    .to_string()
}

/// The `:generate` 200 body: decoded token ids plus per-token latency
/// (raw series and summary quantiles) and KV-cache occupancy.
fn generate_body(model: &str, r: &Response) -> String {
    let Some(d) = &r.decode else {
        return response_body(model, r); // defensive: not a decode answer
    };
    let mut sorted = d.per_token_ms.clone();
    sorted.sort_by(f64::total_cmp);
    json::obj(vec![
        ("model", json::s(model)),
        (
            "tokens",
            json::arr(d.tokens.iter().map(|&t| json::num(t as f64)).collect()),
        ),
        (
            "per_token_ms",
            json::arr(d.per_token_ms.iter().map(|&v| json::num(v)).collect()),
        ),
        ("tok_p50_ms", json::num(quantile_sorted(&sorted, 0.5))),
        ("tok_p95_ms", json::num(quantile_sorted(&sorted, 0.95))),
        ("cache_len", json::num(d.cache_len as f64)),
        ("cached_elems", json::num(d.cached_elems as f64)),
        ("queue_ms", json::num(r.queue_ms)),
        ("total_ms", json::num(r.total_ms)),
    ])
    .to_string()
}

fn error_body(msg: &str) -> String {
    json::obj(vec![("error", json::s(msg))]).to_string()
}

fn models_body(router: &Router) -> String {
    let names = router.served_models();
    // `models` stays a plain name array (the stable roster contract
    // pinned by tests/http.rs); `detail` carries each worker executor's
    // self-description — kind, shapes, batching mode, and for graph
    // workers the layer count and per-layer numeric plan.
    let mut detail = std::collections::BTreeMap::new();
    for m in &names {
        if let Ok(meta) = router.model_meta(m) {
            // Live health from the worker's breaker state
            // (`ok|degraded|restarting`), refreshed per scrape — the
            // rest of the meta is static executor self-description.
            let meta = match (meta, router.health(m)) {
                (json::Value::Obj(mut obj), Ok(h)) => {
                    obj.insert("health".to_string(), json::s(h.state.health_label()));
                    json::Value::Obj(obj)
                }
                (meta, _) => meta,
            };
            detail.insert(m.clone(), meta);
        }
    }
    json::obj(vec![
        (
            "models",
            json::arr(names.iter().map(|m| json::s(m)).collect()),
        ),
        ("detail", json::Value::Obj(detail)),
    ])
    .to_string()
}

/// Prometheus exposition of every worker's [`ServerStats`] plus the
/// front door's [`HttpStats`].
fn metrics_body(router: &Router, http: &HttpStats) -> String {
    use std::fmt::Write as _;

    let mut rows: Vec<(String, ServerStats)> = Vec::new();
    for m in router.served_models() {
        if let Ok(s) = router.stats(&m) {
            rows.push((m, s));
        }
    }
    let mut out = String::new();
    emit(
        &mut out,
        "abfp_requests_total",
        "counter",
        "Requests served successfully.",
        &rows,
        |s| s.requests as f64,
    );
    emit(
        &mut out,
        "abfp_failed_requests_total",
        "counter",
        "Requests answered with an execution error.",
        &rows,
        |s| s.failed_requests as f64,
    );
    emit(
        &mut out,
        "abfp_shed_requests_total",
        "counter",
        "Requests shed 503 for blowing their service deadline while queued.",
        &rows,
        |s| s.shed_requests as f64,
    );
    emit(
        &mut out,
        "abfp_unavailable_requests_total",
        "counter",
        "Requests answered with a retryable 503 (fault, guard trip, or mid-restart).",
        &rows,
        |s| s.unavailable_requests as f64,
    );
    emit(
        &mut out,
        "abfp_batches_total",
        "counter",
        "Device batches executed successfully.",
        &rows,
        |s| s.batches as f64,
    );
    emit(
        &mut out,
        "abfp_failed_batches_total",
        "counter",
        "Device batches that failed to execute.",
        &rows,
        |s| s.failed_batches as f64,
    );
    emit(
        &mut out,
        "abfp_worker_wakeups_total",
        "counter",
        "Worker batch-collection rounds (continuous-batching wakeups).",
        &rows,
        |s| s.wakeups as f64,
    );
    emit(
        &mut out,
        "abfp_queue_depth",
        "gauge",
        "Requests queued on the worker right now.",
        &rows,
        |s| s.queue_depth as f64,
    );
    emit(
        &mut out,
        "abfp_batch_size_mean",
        "gauge",
        "Mean requests per executed batch.",
        &rows,
        |s| s.mean_batch,
    );
    emit(
        &mut out,
        "abfp_exec_ms_mean",
        "gauge",
        "Mean device execution time per batch (ms).",
        &rows,
        |s| s.mean_exec_ms,
    );

    // Executed-batch size histogram (cumulative buckets, Prometheus
    // histogram convention: _bucket/_sum/_count).
    let _ = writeln!(out, "# HELP abfp_batch_size Executed batch sizes.");
    let _ = writeln!(out, "# TYPE abfp_batch_size histogram");
    for (m, s) in &rows {
        let mut cum = 0u64;
        for (le, n) in &s.batch_hist {
            cum += n;
            let le = if le.is_infinite() {
                "+Inf".to_string()
            } else {
                format!("{le}")
            };
            let _ = writeln!(
                out,
                "abfp_batch_size_bucket{{model=\"{m}\",le=\"{le}\"}} {cum}"
            );
        }
        // Sum of batch sizes == successfully served requests.
        let _ = writeln!(out, "abfp_batch_size_sum{{model=\"{m}\"}} {}", s.requests);
        let _ = writeln!(out, "abfp_batch_size_count{{model=\"{m}\"}} {}", s.batches);
    }

    let _ = writeln!(
        out,
        "# HELP abfp_latency_ms Request latency (queue + batch wait + execution)."
    );
    let _ = writeln!(out, "# TYPE abfp_latency_ms gauge");
    for (m, s) in &rows {
        let _ = writeln!(
            out,
            "abfp_latency_ms{{model=\"{m}\",quantile=\"0.5\"}} {}",
            fmt_prom(s.p50_ms)
        );
        let _ = writeln!(
            out,
            "abfp_latency_ms{{model=\"{m}\",quantile=\"0.95\"}} {}",
            fmt_prom(s.p95_ms)
        );
    }

    // Autoregressive decode (`:generate`) counters and gauges.
    emit(
        &mut out,
        "abfp_decode_requests_total",
        "counter",
        ":generate decode requests completed.",
        &rows,
        |s| s.decode_requests as f64,
    );
    emit(
        &mut out,
        "abfp_decode_tokens_total",
        "counter",
        "New tokens decoded across :generate requests.",
        &rows,
        |s| s.decode_tokens as f64,
    );
    emit(
        &mut out,
        "abfp_decode_cache_elems",
        "gauge",
        "KV-cache elements held after the most recent decode.",
        &rows,
        |s| s.cache_elems as f64,
    );

    // Per-token decode latency histogram (cumulative buckets).
    let _ = writeln!(
        out,
        "# HELP abfp_decode_token_ms Per-token decode latency \
         (ms; token 0 includes prompt prefill)."
    );
    let _ = writeln!(out, "# TYPE abfp_decode_token_ms histogram");
    for (m, s) in &rows {
        let mut cum = 0u64;
        for (le, n) in &s.decode_hist {
            cum += n;
            let le = if le.is_infinite() {
                "+Inf".to_string()
            } else {
                format!("{le}")
            };
            let _ = writeln!(
                out,
                "abfp_decode_token_ms_bucket{{model=\"{m}\",le=\"{le}\"}} {cum}"
            );
        }
        let _ = writeln!(
            out,
            "abfp_decode_token_ms_sum{{model=\"{m}\"}} {}",
            fmt_prom(s.decode_ms_sum)
        );
        let _ = writeln!(
            out,
            "abfp_decode_token_ms_count{{model=\"{m}\"}} {}",
            s.decode_tokens
        );
    }

    let _ = writeln!(
        out,
        "# HELP abfp_decode_token_latency_ms Per-token decode latency quantiles."
    );
    let _ = writeln!(out, "# TYPE abfp_decode_token_latency_ms gauge");
    for (m, s) in &rows {
        let _ = writeln!(
            out,
            "abfp_decode_token_latency_ms{{model=\"{m}\",quantile=\"0.5\"}} {}",
            fmt_prom(s.tok_p50_ms)
        );
        let _ = writeln!(
            out,
            "abfp_decode_token_latency_ms{{model=\"{m}\",quantile=\"0.95\"}} {}",
            fmt_prom(s.tok_p95_ms)
        );
    }

    // Supervision: per-model breaker state and degradation counters
    // (lock-free atomics on the worker's HealthState).
    let health: Vec<(String, HealthSnapshot)> = router
        .served_models()
        .into_iter()
        .filter_map(|m| router.health(&m).ok().map(|h| (m, h)))
        .collect();
    let breaker_metrics: [(&str, &str, &str, fn(&HealthSnapshot) -> f64); 6] = [
        (
            "abfp_breaker_state",
            "gauge",
            "Circuit-breaker state (0=closed, 1=open, 2=half_open, 3=restarting).",
            |h| h.state.code() as f64,
        ),
        (
            "abfp_worker_restarts_total",
            "counter",
            "Successful executor rebuilds after a panic or failed restart.",
            |h| h.restarts as f64,
        ),
        (
            "abfp_fallback_batches_total",
            "counter",
            "Batches served by the FLOAT32 host-reference fallback.",
            |h| h.fallback_batches as f64,
        ),
        (
            "abfp_fault_events_total",
            "counter",
            "Fault-class failures observed (guard trips, outages, panics).",
            |h| h.faults as f64,
        ),
        (
            "abfp_breaker_probes_total",
            "counter",
            "HalfOpen probe attempts against the primary plan.",
            |h| h.probes as f64,
        ),
        (
            "abfp_breaker_rearms_total",
            "counter",
            "Probes that succeeded and re-armed the analog plan.",
            |h| h.rearms as f64,
        ),
    ];
    for (name, kind, help, get) in breaker_metrics {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        for (m, h) in &health {
            let _ = writeln!(out, "{name}{{model=\"{m}\"}} {}", fmt_prom(get(h)));
        }
    }

    // Front-door (event-loop) counters: no model label.
    let scalars: [(&str, &str, &str, u64); 4] = [
        (
            "abfp_http_wakeups_total",
            "counter",
            "Event-loop poll wakeups across the pool.",
            http.wakeups(),
        ),
        (
            "abfp_http_connections_accepted_total",
            "counter",
            "Connections accepted.",
            http.accepted(),
        ),
        (
            "abfp_http_connections_open",
            "gauge",
            "Connections open right now.",
            http.open(),
        ),
        (
            "abfp_http_connections_reaped_total",
            "counter",
            "Connections closed by deadline or write stall.",
            http.reaped(),
        ),
    ];
    for (name, kind, help, v) in scalars {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        let _ = writeln!(out, "{name} {v}");
    }
    out
}

fn emit(
    out: &mut String,
    name: &str,
    kind: &str,
    help: &str,
    rows: &[(String, ServerStats)],
    get: impl Fn(&ServerStats) -> f64,
) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
    for (m, s) in rows {
        let _ = writeln!(out, "{name}{{model=\"{m}\"}} {}", fmt_prom(get(s)));
    }
}

/// Prometheus float spelling (`NaN` / `+Inf` / `-Inf`, not Rust's
/// `inf`). Stats are finite by construction, but the scrape must never
/// be the thing that breaks.
fn fmt_prom(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{v}")
    }
}

fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Find `\r\n\r\n` searching only from `from` (resumable scan).
fn find_head_end_from(buf: &[u8], from: usize) -> Option<usize> {
    if buf.len() < 4 {
        return None;
    }
    buf[from.min(buf.len())..]
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .map(|i| i + from)
}

/// Parse request line + headers. Returns
/// `(method, path, keep_alive, content_length, expect_continue)`.
#[allow(clippy::type_complexity)]
fn parse_head(head: &str) -> Result<(String, String, bool, usize, bool), HttpError> {
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut it = request_line.split_whitespace();
    let (method, path, version) = match (it.next(), it.next(), it.next()) {
        (Some(m), Some(p), Some(v)) => (m.to_string(), p.to_string(), v),
        _ => {
            return Err(HttpError::new(
                400,
                format!("malformed request line {request_line:?}"),
            ))
        }
    };
    let mut keep_alive = version == "HTTP/1.1";
    let mut content_length = 0usize;
    let mut expect_continue = false;
    for line in lines {
        let Some((name, value)) = line.split_once(':') else {
            continue;
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim();
        match name.as_str() {
            "content-length" => {
                content_length = value.parse().map_err(|_| {
                    HttpError::new(400, format!("bad content-length {value:?}"))
                })?;
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.contains("close") {
                    keep_alive = false;
                } else if v.contains("keep-alive") {
                    keep_alive = true;
                }
            }
            "transfer-encoding" => {
                return Err(HttpError::new(
                    400,
                    "transfer-encoding is not supported; send content-length",
                ));
            }
            "expect" => {
                expect_continue = value.eq_ignore_ascii_case("100-continue");
            }
            _ => {}
        }
    }
    Ok((method, path, keep_alive, content_length, expect_continue))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn head_parsing() {
        let head = "POST /v1/models/cnn:predict HTTP/1.1\r\nHost: x\r\nContent-Length: 12\r\nConnection: close";
        let (m, p, ka, cl, ec) = parse_head(head).unwrap();
        assert_eq!(m, "POST");
        assert_eq!(p, "/v1/models/cnn:predict");
        assert!(!ka);
        assert_eq!(cl, 12);
        assert!(!ec);
        // HTTP/1.1 defaults to keep-alive; header names are
        // case-insensitive; expect is honoured.
        let (_, _, ka, _, ec) =
            parse_head("GET / HTTP/1.1\r\ncOnTeNt-LeNgTh: 3\r\nExpect: 100-continue")
                .unwrap();
        assert!(ka);
        assert!(ec);
        let (_, _, ka, _, _) = parse_head("GET / HTTP/1.0").unwrap();
        assert!(!ka);
        assert!(parse_head("garbage").is_err());
        assert!(parse_head("GET / HTTP/1.1\r\ncontent-length: x").is_err());
        assert!(parse_head("GET / HTTP/1.1\r\ntransfer-encoding: chunked").is_err());
    }

    #[test]
    fn tensor_body_parsing() {
        let v = json::parse(r#"{"data": [1, 2, 3, 4], "shape": [2, 2]}"#).unwrap();
        let t = parse_tensor(&v).unwrap();
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[1.0, 2.0, 3.0, 4.0]);
        // Shape defaults to flat.
        let v = json::parse(r#"{"data": [1, 2]}"#).unwrap();
        assert_eq!(parse_tensor(&v).unwrap().shape(), &[2]);
        // Mismatched shape, missing data, non-numeric data: errors.
        assert!(parse_tensor(&json::parse(r#"{"data":[1],"shape":[3]}"#).unwrap())
            .is_err());
        assert!(parse_tensor(&json::parse(r#"{"shape":[1]}"#).unwrap()).is_err());
        assert!(parse_tensor(&json::parse(r#"{"data":[null]}"#).unwrap()).is_err());
    }

    #[test]
    fn head_end_detection() {
        assert_eq!(find_head_end_from(b"GET / HTTP/1.1\r\n\r\nrest", 0), Some(14));
        assert_eq!(find_head_end_from(b"partial\r\n", 0), None);
        // Resumable scan: the terminator is found even when the search
        // resumes 3 bytes before a chunk boundary that splits it.
        let buf = b"GET / HTTP/1.1\r\n\r\n";
        assert_eq!(find_head_end_from(buf, buf.len() - 4), Some(14));
        assert_eq!(find_head_end_from(buf, 14), Some(14));
        assert_eq!(find_head_end_from(buf, 15), None);
        assert_eq!(find_head_end_from(b"ab", 0), None);
    }

    #[test]
    fn prometheus_float_spelling() {
        assert_eq!(fmt_prom(1.5), "1.5");
        assert_eq!(fmt_prom(f64::NAN), "NaN");
        assert_eq!(fmt_prom(f64::INFINITY), "+Inf");
        assert_eq!(fmt_prom(f64::NEG_INFINITY), "-Inf");
    }

    /// A Conn over a throwaway loopback socket, for driving the parse
    /// state machine directly (no event loop).
    fn test_conn() -> Conn {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = std::net::TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let _accepted = listener.accept().unwrap();
        Conn::new(stream)
    }

    #[test]
    fn extraction_handles_split_and_pipelined_requests() {
        let mut c = test_conn();
        // First request arrives split mid-head, then mid-body, with a
        // second request pipelined right behind it.
        c.buf.extend_from_slice(b"POST /x HTTP/1.1\r\ncontent-");
        assert!(c.try_extract().unwrap().is_none());
        c.buf.extend_from_slice(b"length: 5\r\n\r\nab");
        assert!(c.try_extract().unwrap().is_none());
        c.buf.extend_from_slice(b"cdeGET /healthz HTTP/1.1\r\n\r\n");
        let req = c.try_extract().unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"abcde");
        assert!(req.keep_alive);
        let req = c.try_extract().unwrap().unwrap();
        assert_eq!((req.method.as_str(), req.path.as_str()), ("GET", "/healthz"));
        assert!(req.body.is_empty());
        assert!(c.try_extract().unwrap().is_none());
        assert!(c.buf.is_empty());
    }

    #[test]
    fn extraction_sends_100_continue_once_and_caps_the_body() {
        let mut c = test_conn();
        c.buf.extend_from_slice(
            b"POST /x HTTP/1.1\r\ncontent-length: 9\r\nexpect: 100-continue\r\n\r\n",
        );
        assert!(c.try_extract().unwrap().is_none());
        assert_eq!(c.out, b"HTTP/1.1 100 Continue\r\n\r\n");
        // Only once per request.
        assert!(c.try_extract().unwrap().is_none());
        assert_eq!(c.out.len(), 25);
        c.buf.extend_from_slice(b"012345678");
        assert_eq!(c.try_extract().unwrap().unwrap().body, b"012345678");

        // An oversized declared body is refused from the head alone.
        let mut c = test_conn();
        let head = format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n", MAX_BODY + 1);
        c.buf.extend_from_slice(head.as_bytes());
        assert_eq!(c.try_extract().unwrap_err().status, 413);
    }

    #[test]
    fn interest_follows_connection_state() {
        let mut c = test_conn();
        assert_eq!(c.interest(), READABLE);
        c.out.extend_from_slice(b"x");
        assert_eq!(c.interest(), READABLE | WRITABLE);
        let (tx, rx) = std::sync::mpsc::channel();
        drop(tx);
        c.pending = Some(Pending {
            rx,
            model: "m".into(),
            head_only: false,
            keep_alive: true,
            generate: false,
        });
        // In flight: reads pause (ordering + backpressure), write
        // interest persists.
        assert_eq!(c.interest(), WRITABLE);
        c.pending = None;
        c.out.clear();
        c.draining = Some(Instant::now());
        assert_eq!(c.interest(), READABLE);
    }
}
