//! # ABFP — Adaptive Block Floating-Point for Analog Deep Learning Hardware
//!
//! A production-grade reproduction of Basumallik et al. (2022) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 1/2 (build time)**: the ABFP Pallas kernel and the six
//!   MLPerf-archetype models live in `python/compile/` and are AOT-lowered
//!   to HLO-text artifacts (`make artifacts`).
//! * **Layer 3 (this crate)**: everything at run time — the PJRT
//!   [`runtime`], the serving [`coordinator`] (router + dynamic batcher
//!   + a std-only HTTP/1.1 front door and load generator — the MLPerf
//!   server-scenario boundary), the pluggable number-format
//!   [`backend`]s, the bit-exact [`abfp`] device simulator, the [`dnf`]
//!   finetuning machinery, the [`energy`] model, synthetic [`data`]
//!   generators, task [`metrics`], and the [`sweep`] drivers that
//!   regenerate every table and figure of the paper. Python never runs
//!   on the request path.
//!
//! ## Numeric backends
//!
//! The paper's central comparison — ABFP against other number
//! representations on the same workloads — is a first-class API seam:
//! [`backend::NumericBackend`] with four shipped implementations
//! (`float32`, `abfp`, `fixed`, `bfp`). The contract mirrors the
//! hardware: [`backend::NumericBackend::stage_weights`] converts a
//! weight matrix into the backend's native form **once** (weights live
//! on the analog array); [`backend::NumericBackend::matmul`] runs the
//! full numeric pipeline against the pre-staged weights, converting
//! activations per call. Every sweep driver, the serving coordinator
//! and the CLI (`--backend {float32,abfp,fixed,bfp}`) select backends
//! through [`backend::BackendKind`]; adding a representation (RNS,
//! AdaptivFloat, …) is one file plus one enum arm.
//!
//! ## Model executors & graph serving
//!
//! The serving-side twin of that seam is
//! [`coordinator::ModelExecutor`]: one worker loop, three pluggable
//! execution engines (echo / graph / PJRT). The [`graph`] subsystem
//! makes whole-model inference native Rust — a [`graph::ModelGraph`]
//! layer IR with deterministic seeded builders for all six archetypes,
//! executed under a [`graph::GraphPlan`]: a **per-layer** assignment of
//! backend + device point (JSON round-trippable), so "FLOAT32 edges,
//! ABFP interior at gain 4" is a config file. `serve --graph` /
//! `bench-serve --graph` serve real multi-layer traffic on a fresh
//! checkout with no artifacts; `eval-graph` reports per-layer
//! saturation/conversion accounting.
//!
//! ## Determinism & parallel execution
//!
//! Every simulator-backend matmul is **bit-exact across thread counts
//! and batch splits**. The one stochastic component — the ABFP ADC
//! noise of Eq. 5 — is *coordinate-keyed*: the draw at output
//! `(row, col)`, tile `ti` is a pure function of
//! `(seed, global_row, col, ti)` ([`rng::CounterRng`], a SplitMix64
//! counter RNG), never of evaluation order. Matmuls therefore run 2-D
//! cell-chunked — row × column-block cells, so even a batch-1 request
//! against a wide layer fans out across every core — on a
//! dependency-free scoped thread pool ([`parallel`], `std::thread`
//! only); the CLI `--threads` flag (default: all cores) sets the
//! process-wide worker count, and `tests/determinism.rs` pins the
//! invariance for every thread count and block width. The request hot
//! path is allocation-free once warm:
//! [`backend::NumericBackend::matmul_into`] stages into a reusable
//! [`backend::Scratch`] and writes into a reusable output tensor, and
//! [`graph::GraphExecutor`] pools its activations (see
//! `rust/README.md` §Performance). (The ABFP *PJRT-artifact* serving
//! path keys its noise per executed batch inside the kernel, outside
//! this contract.)
//!
//! ## Offline substrate
//!
//! No crates.io registry is available in the build environment, so the
//! two external dependencies are vendored under `rust/vendor/`
//! (`anyhow` as anyhow-lite; `xla` as a host-side stub whose PJRT entry
//! points are gated behind clear errors until the real bindings are
//! swapped in). The classic support crates are implemented in-repo:
//! [`rng`] (PCG64 + distributions), [`json`], [`cli`], [`benchkit`]
//! (criterion-lite), and [`stats`].

#![forbid(unsafe_code)]

pub mod abfp;
pub mod analysis;
pub mod backend;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dnf;
pub mod energy;
pub mod fault;
pub mod graph;
pub mod json;
pub mod metrics;
pub mod models;
pub mod numerics;
pub mod parallel;
pub mod planner;
pub mod report;
pub mod rng;
pub mod runtime;
pub mod stats;
pub mod sweep;
pub mod tensor;
pub mod train;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
