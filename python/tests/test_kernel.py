"""L1 correctness: Pallas ABFP kernel vs the pure-jnp oracle.

The core signal of the build-time test suite: for every shape / tile /
bitwidth / gain / noise combination, the Pallas kernel must agree with
``compile.kernels.ref`` to within one BFLOAT16 ULP of the accumulated
output (FLOAT32 accumulation order may differ between the einsum oracle
and the sequential grid, which can flip the final BFLOAT16 rounding).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import abfp, ref

jax.config.update("jax_platform_name", "cpu")


def bf16_ulp_bound(out: jnp.ndarray) -> jnp.ndarray:
    """Two BFLOAT16 ULPs at each output magnitude (accumulation slack)."""
    mag = jnp.maximum(jnp.abs(out), 2.0 ** -126)
    exp = jnp.floor(jnp.log2(mag))
    return 2.0 * 2.0 ** (exp - 7)


def run_both(x, w, n, gain, bw, bx, by, amp, seed=0):
    t = ref.num_tiles(x.shape[1], n)
    dy = ref.delta(by)
    if amp > 0:
        noise = ref.sample_noise(
            jax.random.PRNGKey(seed), t, x.shape[0], w.shape[0], n, dy, amp)
    else:
        noise = jnp.zeros((t, x.shape[0], w.shape[0]), jnp.float32)
    r = ref.abfp_matmul(x, w, n=n, gain=gain, delta_w=ref.delta(bw),
                        delta_x=ref.delta(bx), delta_y=dy, noise=noise)
    p = abfp.abfp_matmul(x, w, noise, abfp.make_scalars(gain, bw, bx, by), n=n)
    return np.asarray(r), np.asarray(p)


def assert_kernel_matches(x, w, n, gain=1.0, bw=8, bx=8, by=8, amp=0.0):
    """Contract: kernel == oracle up to FLOAT32 accumulation-order effects.

    Elementwise the results agree within 2 BFLOAT16 ULPs. A pre-ADC value
    sitting within ~1e-6 of a rounding boundary may flip by one whole ADC
    bin between the two evaluation orders; such flips are rare (<2% of
    elements) and bounded by one rescaled output LSB: n*delta_y*sx*sw/G.
    """
    r, p = run_both(x, w, n, gain, bw, bx, by, amp)
    diff = np.abs(r - p)
    bound = np.asarray(bf16_ulp_bound(jnp.asarray(r)))
    viol = diff > bound
    msg = f"n={n} gain={gain} bits={bw}/{bx}/{by} amp={amp}"
    # Each output element accumulates T independently-ADC'd partials, and
    # each partial can flip one rounding boundary between the two
    # evaluation orders — so the allowance scales with the tile count.
    t = ref.num_tiles(x.shape[1], n)
    # Coarse bitwidths (<=4 operand bits) put pre-ADC values on a dense
    # rational grid where order-dependent f32 rounding hits boundaries
    # more often; the allowance floor reflects that.
    allowed = max(3.0, 0.05 * viol.size * t)
    assert viol.sum() <= allowed, f"{viol.sum()} boundary flips; {msg}"
    # Any violator is at most a couple of ADC LSBs of one tile partial.
    parts = ref.abfp_matmul_parts(
        jnp.asarray(x), jnp.asarray(w), n=n, gain=gain,
        delta_w=ref.delta(bw), delta_x=ref.delta(bx), delta_y=ref.delta(by))
    max_scale = float(jnp.max(parts.sx)) * float(jnp.max(parts.sw))
    lsb = n * ref.delta(by) * max_scale / gain
    np.testing.assert_array_less(diff, 2 * lsb + 2 * bound + 1e-30, err_msg=msg)


def rand_inputs(m, k, nn, seed=0, dist="normal"):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    if dist == "laplace":
        x = jax.random.laplace(kx, (m, k))
    else:
        x = jax.random.normal(kx, (m, k))
    w = jax.random.normal(kw, (nn, k)) * 0.5
    return ref.bf16_round(x), ref.bf16_round(w)


# ---------------------------------------------------------------- unit -----

class TestQuantize:
    def test_delta(self):
        assert ref.delta(8) == pytest.approx(1.0 / 127.0)
        assert ref.delta(6) == pytest.approx(1.0 / 31.0)
        assert ref.delta(2) == 1.0

    def test_round_half_even(self):
        d = 1.0
        v = jnp.array([0.5, 1.5, 2.5, -0.5, -1.5])
        out = ref.quantize(v, d, 10.0)
        np.testing.assert_allclose(out, [0.0, 2.0, 2.0, 0.0, -2.0])

    def test_clamp(self):
        out = ref.quantize(jnp.array([5.0, -5.0, 0.26]), 0.5, 1.0)
        np.testing.assert_allclose(out, [1.0, -1.0, 0.5])

    def test_half_bin_rounds_to_even_grid_point(self):
        # 0.25 / 0.5 = 0.5 exactly -> RNE rounds to 0, not 0.5.
        out = ref.quantize(jnp.array([0.25]), 0.5, 1.0)
        np.testing.assert_allclose(out, [0.0])

    def test_quantize_idempotent(self):
        d = ref.delta(6)
        v = jnp.linspace(-1, 1, 101)
        q1 = ref.quantize(v, d, 1.0)
        q2 = ref.quantize(q1, d, 1.0)
        np.testing.assert_allclose(q1, q2)

    def test_grid_membership(self):
        d = ref.delta(8)
        v = jax.random.normal(jax.random.PRNGKey(3), (256,))
        q = ref.quantize(v, d, 1.0)
        ratio = np.asarray(q / d)
        np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-5)


class TestScales:
    def test_zero_tile_scale_is_one(self):
        s = ref.tile_scales(jnp.zeros((2, 3, 8)))
        np.testing.assert_allclose(s, 1.0)

    def test_scale_is_max_abs_bf16(self):
        v = jnp.array([[0.5, -2.0, 1.0, 0.0]])
        s = ref.tile_scales(v)
        assert s[0, 0] == 2.0

    def test_scale_bf16_rounding(self):
        # 1.00390625 rounds to 1.0 in bf16 (RNE on 8-bit mantissa).
        v = jnp.array([[1.00390625]])
        assert ref.tile_scales(v)[0, 0] == 1.0

    def test_pad_to_tiles(self):
        v = jnp.ones((3, 10))
        p = ref.pad_to_tiles(v, 8)
        assert p.shape == (3, 16)
        np.testing.assert_allclose(p[:, 10:], 0.0)
        assert ref.pad_to_tiles(v, 5).shape == (3, 10)


class TestOracleBasics:
    def test_zero_input_zero_output(self):
        x = jnp.zeros((4, 64))
        w = jnp.ones((3, 64))
        out = ref.abfp_matmul(x, w, n=16, gain=1.0, delta_w=ref.delta(8),
                              delta_x=ref.delta(8), delta_y=ref.delta(8))
        np.testing.assert_allclose(out, 0.0)

    def test_identity_like(self):
        # One-hot rows times one-hot columns: the scale absorbs magnitude
        # and the normalized dot is exactly 1.0, recovered up to one ADC
        # bin (1.0 is not on the n*delta_y grid).
        x = jnp.eye(4, 32) * 3.0
        w = jnp.eye(4, 32) * 2.0
        n, by = 8, 8
        out = ref.abfp_matmul(x, w, n=n, gain=1.0, delta_w=ref.delta(8),
                              delta_x=ref.delta(8), delta_y=ref.delta(by))
        adc_bin = n * ref.delta(by) * 6.0  # one output LSB, rescaled
        np.testing.assert_allclose(np.diag(np.asarray(out)), 6.0,
                                   atol=adc_bin)
        np.testing.assert_allclose(
            np.asarray(out) - np.diag(np.diag(np.asarray(out))), 0.0)

    def test_high_bits_close_to_float(self):
        x, w = rand_inputs(8, 96, 8, seed=1)
        out = ref.abfp_matmul(x, w, n=32, gain=1.0, delta_w=ref.delta(16),
                              delta_x=ref.delta(16), delta_y=ref.delta(24))
        fm = ref.float_matmul(x, w)
        np.testing.assert_allclose(out, fm, rtol=2e-2, atol=2e-2)

    def test_pow2_scaling_equivariance(self):
        # Scaling x by a power of two scales the output exactly: the bf16
        # scale absorbs it and the normalized tile is unchanged.
        x, w = rand_inputs(4, 64, 5, seed=2)
        kw = dict(n=16, gain=2.0, delta_w=ref.delta(8),
                  delta_x=ref.delta(8), delta_y=ref.delta(8))
        a = ref.abfp_matmul(x * 4.0, w, **kw)
        b = ref.abfp_matmul(x, w, **kw)
        np.testing.assert_allclose(a, 4.0 * b, rtol=1e-6)

    def test_gain_divided_out_when_no_saturation(self):
        # With tiny inputs and moderate gain nothing saturates; gain only
        # shifts which bits are captured, so high-precision output is ~same.
        x, w = rand_inputs(4, 64, 5, seed=3)
        x, w = x * 0.05, w * 0.05
        kw = dict(n=16, delta_w=ref.delta(8), delta_x=ref.delta(8),
                  delta_y=ref.delta(14))
        a = ref.abfp_matmul(x, w, gain=1.0, **kw)
        b = ref.abfp_matmul(x, w, gain=4.0, **kw)
        np.testing.assert_allclose(a, b, rtol=0.05, atol=1e-3)

    def test_saturation_fraction_increases_with_gain(self):
        x, w = rand_inputs(16, 256, 16, seed=4, dist="laplace")
        sats = []
        for g in [1.0, 4.0, 16.0, 64.0]:
            parts = ref.abfp_matmul_parts(
                x, w, n=128, gain=g, delta_w=ref.delta(8),
                delta_x=ref.delta(8), delta_y=ref.delta(8))
            sats.append(float(parts.sat_frac))
        assert sats == sorted(sats)
        assert sats[-1] > 0.0

    def test_partials_on_adc_grid(self):
        x, w = rand_inputs(4, 64, 5, seed=5)
        n, by = 16, 8
        parts = ref.abfp_matmul_parts(
            x, w, n=n, gain=2.0, delta_w=ref.delta(8),
            delta_x=ref.delta(8), delta_y=ref.delta(by))
        ratio = np.asarray(parts.partial_q) / (n * ref.delta(by))
        np.testing.assert_allclose(ratio, np.round(ratio), atol=1e-3)
        assert np.abs(np.asarray(parts.partial_q)).max() <= n + 1e-6

    def test_error_decreases_with_bits(self):
        x, w = rand_inputs(8, 128, 8, seed=6)
        fm = np.asarray(ref.float_matmul(x, w))
        errs = []
        for b in [4, 6, 8, 12]:
            out = ref.abfp_matmul(x, w, n=8, gain=1.0, delta_w=ref.delta(b),
                                  delta_x=ref.delta(b), delta_y=ref.delta(b + 4))
            errs.append(float(np.mean(np.abs(np.asarray(out) - fm))))
        assert errs == sorted(errs, reverse=True)

    def test_noise_variance_model(self):
        # Paper section III-C: Var(eps) = (n*delta_y)^2 / 12 at 0.5 LSB.
        n, by = 32, 8
        dy = ref.delta(by)
        noise = ref.sample_noise(jax.random.PRNGKey(0), 40, 32, 32, n, dy, 0.5)
        var = float(jnp.var(noise))
        expect = (n * dy) ** 2 / 12.0
        assert abs(var - expect) / expect < 0.05
        assert float(jnp.max(jnp.abs(noise))) <= 0.5 * n * dy + 1e-9


# ---------------------------------------------------- kernel vs oracle -----

GRID_CASES = [
    # (M, K, N, n, gain, bw, bx, by, amp)
    (4, 64, 8, 8, 1.0, 8, 8, 8, 0.0),
    (4, 64, 8, 32, 2.0, 8, 8, 8, 0.0),
    (4, 64, 8, 128, 8.0, 8, 8, 8, 0.0),   # n > K: single padded tile
    (6, 100, 9, 32, 4.0, 6, 6, 8, 0.0),   # ragged K
    (1, 8, 1, 8, 1.0, 8, 8, 8, 0.0),      # degenerate single tile
    (16, 256, 16, 128, 16.0, 8, 8, 8, 0.5),
    (3, 257, 5, 128, 8.0, 6, 6, 8, 0.5),  # ragged with big tile
    (8, 96, 12, 8, 2.0, 4, 4, 6, 0.5),    # low bitwidths
]


@pytest.mark.parametrize("m,k,nn,n,gain,bw,bx,by,amp", GRID_CASES)
def test_kernel_matches_oracle_grid(m, k, nn, n, gain, bw, bx, by, amp):
    x, w = rand_inputs(m, k, nn, seed=m * 7 + k)
    assert_kernel_matches(x, w, n, gain, bw, bx, by, amp)


@pytest.mark.parametrize("gain", [1.0, 2.0, 4.0, 8.0, 16.0])
def test_kernel_matches_oracle_gain_sweep(gain):
    x, w = rand_inputs(8, 192, 10, seed=11, dist="laplace")
    assert_kernel_matches(x, w, 32, gain, 8, 8, 8, 0.5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([1, 3, 8]),
    k=st.sampled_from([1, 17, 64, 130]),
    nn=st.sampled_from([1, 5, 8]),
    n=st.sampled_from([8, 32, 128]),
    gain=st.sampled_from([1.0, 2.0, 4.0, 8.0, 16.0]),
    bits=st.sampled_from([(6, 6, 8), (8, 8, 8), (4, 4, 6)]),
    amp=st.sampled_from([0.0, 0.5]),
    seed=st.integers(0, 2**16),
)
def test_kernel_matches_oracle_hypothesis(m, k, nn, n, gain, bits, amp, seed):
    bw, bx, by = bits
    x, w = rand_inputs(m, k, nn, seed=seed)
    assert_kernel_matches(x, w, n, gain, bw, bx, by, amp)


@settings(max_examples=10, deadline=None)
@given(
    scale_pow=st.integers(-8, 8),
    seed=st.integers(0, 2**16),
)
def test_kernel_pow2_equivariance_hypothesis(scale_pow, seed):
    x, w = rand_inputs(4, 64, 6, seed=seed)
    s = float(2.0 ** scale_pow)
    noise = jnp.zeros((ref.num_tiles(64, 16), 4, 6), jnp.float32)
    sc = abfp.make_scalars(2.0, 8, 8, 8)
    a = abfp.abfp_matmul(x * s, w, noise, sc, n=16)
    b = abfp.abfp_matmul(x, w, noise, sc, n=16)
    np.testing.assert_allclose(np.asarray(a), s * np.asarray(b), rtol=1e-6)


def test_kernel_noiseless_deterministic():
    x, w = rand_inputs(5, 80, 7, seed=21)
    noise = jnp.zeros((ref.num_tiles(80, 32), 5, 7), jnp.float32)
    sc = abfp.make_scalars(4.0, 8, 8, 8)
    a = abfp.abfp_matmul(x, w, noise, sc, n=32)
    b = abfp.abfp_matmul(x, w, noise, sc, n=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
