//! Acceptance tests for the adaptive precision planner subsystem:
//! `plan-search` must emit a valid, strictly cheaper, within-budget
//! plan that actually serves, and `dnf-graph` must demonstrably reduce
//! divergence for a budget-rejected plan — all on a fresh checkout,
//! deterministic seeds throughout.

use abfp::abfp::DeviceConfig;
use abfp::backend::BackendKind;
use abfp::coordinator::{BatchPolicy, Router};
use abfp::data::dataset_for;
use abfp::graph::{GraphPlan, LayerPlan};
use abfp::planner::{dnf_graph, search, DnfGraphConfig, SearchConfig};
use abfp::rng::Pcg64;

#[test]
fn plan_search_emits_a_cheaper_within_budget_plan_that_serves() {
    // The ISSUE acceptance criterion in one test: search gru at a 2%
    // budget, then check the winning plan (1) scores within budget,
    // (2) is strictly cheaper under the energy model than the uniform
    // FLOAT32 start, (3) round-trips through plan JSON on disk exactly,
    // and (4) serves through the graph router.
    let cfg = SearchConfig::smoke(2.0);
    let res = search::run("gru", &cfg).unwrap();

    assert!(
        res.best.divergence.within(2.0),
        "best plan over budget: {:?}",
        res.best.divergence
    );
    assert!(
        res.best.cost.total < res.start.cost.total,
        "search failed to beat the uniform FLOAT32 start: {} vs {}",
        res.best.cost.total,
        res.start.cost.total
    );
    assert_eq!(res.start.divergence.rel_err_pct, 0.0, "start is FLOAT32");
    assert!(res.evals > 0);

    // (3) the emitted JSON is byte-serialised, reloaded, and equal.
    let path = std::env::temp_dir()
        .join(format!("abfp_plan_search_{}.json", std::process::id()));
    std::fs::write(&path, res.best.plan.to_json().to_string()).unwrap();
    let loaded = GraphPlan::load(path.to_str().unwrap()).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, res.best.plan);

    // (4) the loaded plan serves real traffic.
    let router = Router::start_graph(
        &["gru".to_string()],
        &loaded,
        BatchPolicy::new(8, 1).unwrap(),
        64,
        0x5eed,
        1,
    )
    .unwrap();
    let ds = dataset_for("gru").unwrap();
    let b = ds.batch(&mut Pcg64::seeded(3), 1);
    let example_shape: Vec<usize> = b.x.shape()[1..].to_vec();
    let x = b.x.clone().reshape(&example_shape).unwrap();
    let rx = router.submit("gru", x).unwrap();
    rx.recv().unwrap().unwrap();
    assert_eq!(router.stats("gru").unwrap().requests, 1);
}

#[test]
fn search_is_deterministic() {
    let cfg = SearchConfig::smoke(2.0);
    let a = search::run("gru", &cfg).unwrap();
    let b = search::run("gru", &cfg).unwrap();
    assert_eq!(a.best.plan, b.best.plan);
    assert_eq!(a.best.divergence.rel_err_pct, b.best.divergence.rel_err_pct);
    assert_eq!(a.evals, b.evals);
    assert_eq!(a.trajectory.len(), b.trajectory.len());
}

#[test]
fn static_pruning_probes_less_and_matches() {
    // The ISSUE acceptance criterion for the planner integration:
    // static analysis may only *skip* probes whose verdict it proves
    // (digital backends, certified ABFP points) — so at a fixed seed
    // the final plan, its score, and the descent itself are identical
    // with static pruning on or off; only the probe count drops.
    let mut on = SearchConfig::smoke(2.0);
    on.static_prune = true;
    let mut off = on;
    off.static_prune = false;

    let a = search::run("gru", &on).unwrap();
    let b = search::run("gru", &off).unwrap();

    assert_eq!(a.best.plan, b.best.plan);
    assert_eq!(a.best.divergence.rel_err_pct, b.best.divergence.rel_err_pct);
    assert_eq!(a.evals, b.evals);
    assert_eq!(a.pruned, b.pruned);
    assert_eq!(a.trajectory.len(), b.trajectory.len());
    assert!(
        a.probes < b.probes,
        "static pruning skipped nothing: {} vs {} probes",
        a.probes,
        b.probes
    );
    assert_eq!(a.probes + a.probes_skipped, b.probes + b.probes_skipped);
    assert_eq!(b.probes_skipped, 0);
    // The smoke roster carries 2 digital candidates per layer on gru's
    // 3 layers: at least those 6 probes are decided statically.
    assert!(a.probes_skipped >= 6, "{} skipped", a.probes_skipped);
    // The winner carries its lint verdict, and it is Error-free (the
    // probes already vetoed saturating assignments).
    assert!(a.lint.starts_with("0E"), "lint verdict: {}", a.lint);
}

#[test]
fn plan_json_rejects_dead_and_duplicate_layer_indices() {
    // Satellite: explicit per-layer indices beyond every registry
    // model's linear count are dead config (resolve would never read
    // them) — reject at parse time, naming the bound.
    let base = r#"{"default": {"backend": "float32"}, "layers": {"9": {"backend": "fixed"}}}"#;
    let err = GraphPlan::parse(base).unwrap_err().to_string();
    assert!(err.contains("out of range"), "{err}");
    assert!(err.contains('9'), "{err}");

    // Duplicate indices (distinct JSON keys aliasing one layer, e.g.
    // "1" and "01") would silently drop one assignment — reject.
    let dup = r#"{"default": {"backend": "float32"}, "layers": {"1": {"backend": "fixed"}, "01": {"backend": "bfp"}}}"#;
    let err = GraphPlan::parse(dup).unwrap_err().to_string();
    assert!(err.contains("more than once"), "{err}");

    // In-range explicit indices still parse and resolve.
    let ok = r#"{"default": {"backend": "float32"}, "layers": {"2": {"backend": "fixed"}}}"#;
    let plan = GraphPlan::parse(ok).unwrap();
    assert_eq!(plan.resolve(2, 4).backend, BackendKind::Fixed);
    assert_eq!(plan.resolve(1, 4).backend, BackendKind::Float32);
}

#[test]
fn dnf_rescues_a_budget_rejected_plan() {
    // The second ISSUE acceptance criterion: a saturating plan (uniform
    // ABFP at gain 16 — the ADC clips and the output shrinks) fails a
    // 2% budget raw; graph-level DNF with the affine noise model must
    // cut its divergence by at least 10% (the measured improvement is
    // ~25%; 0.9 leaves margin for noise-draw variation while still
    // failing if finetuning regresses). Fixed seeds end to end.
    let plan = GraphPlan::uniform(LayerPlan::new(
        BackendKind::Abfp,
        DeviceConfig::new(0, (8, 8, 8), 16.0, 0.5),
    ));
    let cfg = DnfGraphConfig::default(); // steps 80, lr 2e-3, batch 32
    let out = dnf_graph::run("gru", &plan, &cfg).unwrap();

    assert!(
        !out.before.within(2.0),
        "plan unexpectedly within budget raw: {:?}",
        out.before
    );
    assert!(
        out.after.rel_err_pct < 0.9 * out.before.rel_err_pct,
        "DNF did not reduce divergence enough: before {:.3}% after {:.3}%",
        out.before.rel_err_pct,
        out.after.rel_err_pct
    );
    // The affine calibration saw the saturation shrinkage.
    assert!(
        out.layers.iter().any(|l| l.gamma < 0.95),
        "no shrinkage calibrated: {:?}",
        out.layers
    );
    // Loss actually descended over the schedule.
    assert_eq!(out.losses.len(), cfg.steps);
    let first = out.losses.first().unwrap().loss;
    let last = out.losses.last().unwrap().loss;
    assert!(last < first, "loss did not descend: {first} -> {last}");
}

#[test]
fn planner_assignments_match_graphplan_resolution() {
    // Satellite: the folding from per-layer candidate assignments into
    // GraphPlan's default/first/last/overrides form must resolve back
    // to exactly the assigned candidate for every layer, across every
    // precedence shape (uniform, distinct edges, interior override).
    let cands = search::candidates(true);
    let n = cands.len();
    assert!(n >= 4);
    let cases: Vec<Vec<usize>> = vec![
        vec![0, 0, 0],
        vec![1, 1, 1],
        vec![0, 1, 2],
        vec![1, 0, 1],
        vec![2, 2, 0],
        vec![0, 3, 3, 1],
        vec![3, 0, 0, 0, 3],
        vec![n - 1],
    ];
    for assign in cases {
        let plan = search::plan_from_assignments(&cands, &assign);
        for (i, &c) in assign.iter().enumerate() {
            assert_eq!(
                plan.resolve(i, assign.len()),
                cands[c],
                "assign {assign:?} layer {i}"
            );
        }
        // The planner-emitted JSON text must round-trip through the
        // same loader serve/eval-graph use, auto-tile sentinel (n=0)
        // included.
        let text = plan.to_json().to_string();
        let reparsed = GraphPlan::parse(&text).unwrap();
        assert_eq!(reparsed, plan, "json text: {text}");
        for (i, &c) in assign.iter().enumerate() {
            assert_eq!(reparsed.resolve(i, assign.len()), cands[c]);
        }
    }
    // The smoke roster really carries the sentinel: every non-float32,
    // non-explicit-tile candidate survives the text round-trip with
    // n=0 intact.
    assert!(
        cands.iter().any(|c| c.device.n == 0 && c.backend != BackendKind::Float32),
        "roster lost its auto-tile candidates"
    );
}
