//! Pluggable number-format backends: the seam between workloads
//! (sweeps, serving, finetuning) and numeric simulations.
//!
//! The paper's central comparison is ABFP *versus other number
//! representations* on the same workloads. [`NumericBackend`] makes that
//! comparison a first-class API:
//!
//! * [`NumericBackend::stage_weights`] converts a weight matrix into the
//!   backend's native representation **once** — the paper's "weights are
//!   converted to ABFP once and stored on the analog array". Staged
//!   weights are shareable and cacheable (the serving coordinator stages
//!   at worker startup, not per batch).
//! * [`NumericBackend::matmul`] multiplies a FLOAT32 activation batch
//!   against pre-staged weights, simulating the backend's full numeric
//!   pipeline (DAC/ADC quantization, scales, gain, noise — whatever the
//!   format defines).
//! * [`NumericBackend::stats`] reports saturation/conversion accounting
//!   uniformly across formats.
//!
//! Four implementations ship in-tree:
//!
//! | backend   | scale granularity      | scale type       | output path |
//! |-----------|------------------------|------------------|-------------|
//! | `float32` | —                      | —                | exact       |
//! | `abfp`    | per vector-tile (n)    | BFLOAT16 absmax  | analog ADC  |
//! | `fixed`   | one global per tensor  | FLOAT32 absmax   | digital     |
//! | `bfp`     | per vector-tile (n)    | power of two     | digital     |
//!
//! `fixed` is the paper's INT-b straw man; `bfp` is static block
//! floating-point à la Drumond et al. (HBFP). Adding a backend = one
//! file implementing the trait plus a [`BackendKind`] arm; every sweep,
//! the CLI `--backend` flag and the coordinator pick it up from there.

pub mod abfp;
pub mod bfp;
pub mod fixed;
pub mod float32;

pub use abfp::AbfpBackend;
pub use bfp::BfpStaticBackend;
pub use fixed::FixedPointBackend;
pub use float32::Float32Backend;

use anyhow::{bail, Result};

use crate::abfp::DeviceConfig;
use crate::json::{self, Value};
use crate::numerics::num_tiles;
use crate::tensor::Tensor;

/// Error / utilization accounting shared by every backend.
#[derive(Debug, Clone, Copy, Default)]
pub struct BackendStats {
    /// Matmuls executed since construction / last reset.
    pub matmuls: u64,
    /// Multiply-accumulates performed.
    pub macs: u64,
    /// Quantized output conversions (ADC samples for ABFP; quantized
    /// digital outputs otherwise; zero for the FLOAT32 twin).
    pub conversions: u64,
    /// Conversions that clamped at the representable range.
    pub saturated: u64,
}

impl BackendStats {
    /// Fraction of conversions that saturated.
    pub fn sat_frac(&self) -> f64 {
        if self.conversions == 0 {
            0.0
        } else {
            self.saturated as f64 / self.conversions as f64
        }
    }
}

/// All row-tiles of one (rows, K) operand staged flat: per-tile scales
/// plus quantized normalized values, zero-padded to the tile width `n`
/// — one allocation instead of rows*tiles (perf pass iteration 1).
///
/// Shared representation for the tiled formats (ABFP's BFLOAT16-scaled
/// tiles and static BFP's power-of-two tiles).
#[derive(Debug, Clone, Default)]
pub struct StagedTiles {
    pub rows: usize,
    /// Unpadded reduction length.
    pub k: usize,
    /// Tile width.
    pub n: usize,
    /// Tiles per row.
    pub tiles: usize,
    /// Per-tile scales, rows * tiles.
    pub scales: Vec<f32>,
    /// Quantized normalized values, rows * tiles * n (zero-padded).
    pub q: Vec<f32>,
}

impl StagedTiles {
    /// Empty staging buffers for a (rows, k) operand at tile width n.
    pub fn with_capacity(rows: usize, k: usize, n: usize) -> StagedTiles {
        let mut staged = StagedTiles::default();
        staged.reset(rows, k, n);
        staged
    }

    /// Re-dimension for a (rows, k) operand at tile width n, reusing
    /// the existing allocations (the zero-allocation staging contract:
    /// no growth once warm at a fixed geometry). Stagers overwrite
    /// every `q` slot they cover, so grown space is zero-filled but a
    /// reused prefix is left to the writer.
    pub fn reset(&mut self, rows: usize, k: usize, n: usize) {
        self.rows = rows;
        self.k = k;
        self.n = n;
        self.tiles = num_tiles(k, n);
        self.scales.clear();
        self.scales.reserve(rows * self.tiles);
        self.q.resize(rows * self.tiles * n, 0.0);
    }

    /// The `row_tile`-th length-n quantized tile.
    #[inline]
    pub fn tile(&self, row_tile: usize) -> &[f32] {
        &self.q[row_tile * self.n..(row_tile + 1) * self.n]
    }

    /// Project back to FLOAT32: `scale * q` per tile, padding dropped.
    pub fn dequantize(&self) -> Tensor {
        let mut out = vec![0.0f32; self.rows * self.k];
        for r in 0..self.rows {
            for ti in 0..self.tiles {
                let scale = self.scales[r * self.tiles + ti];
                let tile = self.tile(r * self.tiles + ti);
                let lo = ti * self.n;
                let hi = ((ti + 1) * self.n).min(self.k);
                for (c, &qv) in (lo..hi).zip(tile.iter()) {
                    out[r * self.k + c] = qv * scale;
                }
            }
        }
        Tensor::new(&[self.rows, self.k], out).expect("staged dims")
    }
}

/// Weights staged once into a backend's native representation.
///
/// Opaque to callers: produced by [`NumericBackend::stage_weights`],
/// consumed by the *same* backend's [`NumericBackend::matmul`] (a
/// mismatch is an error, not a silent misread). [`dequantize`]
/// (`StagedWeights::dequantize`) projects the staged values back onto
/// FLOAT32 for weight-residency evaluations.
#[derive(Debug, Clone)]
pub struct StagedWeights {
    backend: &'static str,
    rows: usize,
    k: usize,
    repr: Repr,
}

#[derive(Debug, Clone)]
enum Repr {
    /// FLOAT32 twin: weights pass through unchanged.
    Dense(Tensor),
    /// Per-tile scale + normalized quantized values (ABFP, static BFP).
    Tiled(StagedTiles),
    /// One global scale over the whole tensor (fixed point).
    Global { scale: f32, q: Vec<f32> },
}

impl StagedWeights {
    pub(crate) fn dense(backend: &'static str, w: Tensor) -> StagedWeights {
        let (rows, k) = (w.shape()[0], w.shape()[1]);
        StagedWeights {
            backend,
            rows,
            k,
            repr: Repr::Dense(w),
        }
    }

    pub(crate) fn tiled(backend: &'static str, t: StagedTiles) -> StagedWeights {
        StagedWeights {
            backend,
            rows: t.rows,
            k: t.k,
            repr: Repr::Tiled(t),
        }
    }

    pub(crate) fn global(
        backend: &'static str,
        rows: usize,
        k: usize,
        scale: f32,
        q: Vec<f32>,
    ) -> StagedWeights {
        StagedWeights {
            backend,
            rows,
            k,
            repr: Repr::Global { scale, q },
        }
    }

    /// Name of the backend that staged these weights.
    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Output features (N).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Reduction length (K).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Project the staged representation back onto FLOAT32 (rows, K) —
    /// the weight matrix as the device actually stores it.
    pub fn dequantize(&self) -> Tensor {
        match &self.repr {
            Repr::Dense(w) => w.clone(),
            Repr::Tiled(t) => t.dequantize(),
            Repr::Global { scale, q } => {
                Tensor::new(&[self.rows, self.k], q.iter().map(|v| v * scale).collect())
                    .expect("staged dims")
            }
        }
    }

    fn expect_backend(&self, who: &str) -> Result<()> {
        if self.backend != who {
            bail!(
                "staged weights belong to backend {:?}, not {who:?}; restage with the right backend",
                self.backend
            );
        }
        Ok(())
    }

    pub(crate) fn expect_dense(&self, who: &str) -> Result<&Tensor> {
        self.expect_backend(who)?;
        match &self.repr {
            Repr::Dense(w) => Ok(w),
            _ => bail!("{who}: staged representation is not dense"),
        }
    }

    pub(crate) fn expect_tiled(&self, who: &str) -> Result<&StagedTiles> {
        self.expect_backend(who)?;
        match &self.repr {
            Repr::Tiled(t) => Ok(t),
            _ => bail!("{who}: staged representation is not tiled"),
        }
    }

    pub(crate) fn expect_global(&self, who: &str) -> Result<(f32, &[f32])> {
        self.expect_backend(who)?;
        match &self.repr {
            Repr::Global { scale, q } => Ok((*scale, q)),
            _ => bail!("{who}: staged representation is not global-scale"),
        }
    }
}

/// Reusable per-call buffers for [`NumericBackend::matmul_into`]: the
/// activation-side staging a backend performs per matmul (the weight
/// side is staged once into [`StagedWeights`]). Hold one `Scratch` per
/// (backend, call-site) pairing and the backend stops allocating on
/// the request path once the buffers are warm. Contents are opaque —
/// backends fully overwrite whatever they use, so one scratch can be
/// shared across differently-shaped calls (at the cost of regrowth).
#[derive(Debug, Default)]
pub struct Scratch {
    /// Tiled activation staging (the abfp / bfp kernels).
    pub(crate) tiles: StagedTiles,
    /// Globally-scaled quantized activations (the fixed-point kernel).
    pub(crate) qx: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }
}

/// A pluggable number-format simulation.
///
/// Contract: `matmul` computes `x (M,K) @ w^T (N,K) -> (M,N)` where `w`
/// was staged by **this** backend's `stage_weights`. Activations are
/// converted per call (the device's DAC path); weights are staged once.
///
/// Determinism contract: `matmul` output must be a pure function of
/// `(backend state, x, staged weights)` — independent of thread count
/// and of how a batch is split across calls (ABFP's ADC noise is
/// coordinate-keyed to guarantee this; see `crate::abfp`). Backends are
/// `Send + Sync` plain data so staged weights and the simulators
/// themselves can be shared across the worker threads that
/// `crate::parallel` spawns.
pub trait NumericBackend: Send + Sync {
    /// Short stable identifier (`float32`, `abfp`, `fixed`, `bfp`).
    fn name(&self) -> &'static str;

    /// The exact configuration, machine-readable — recorded in sweep
    /// reports and the serve startup log so results are reproducible.
    fn config_json(&self) -> Value;

    /// Convert a 2-D (N, K) weight matrix into the backend's native
    /// representation. Done once per weight matrix; the result is
    /// shareable across calls and threads (it is plain data).
    fn stage_weights(&self, w: &Tensor) -> Result<StagedWeights>;

    /// The hot-path seam: `x (M,K) @ staged^T -> (M,N)` under the
    /// backend's numerics, staging activations into `scratch` and
    /// writing the product into `out` — both reuse their allocations
    /// across calls, so a warm serving worker performs no heap
    /// allocation here. Bit-identical to [`matmul`](Self::matmul).
    fn matmul_into(
        &mut self,
        x: &Tensor,
        w: &StagedWeights,
        scratch: &mut Scratch,
        out: &mut Tensor,
    ) -> Result<()>;

    /// `x (M,K) @ staged^T -> (M,N)` under the backend's numerics.
    /// Allocating convenience over [`matmul_into`](Self::matmul_into).
    fn matmul(&mut self, x: &Tensor, w: &StagedWeights) -> Result<Tensor> {
        let mut scratch = Scratch::default();
        let mut out = Tensor::from_vec(Vec::new());
        self.matmul_into(x, w, &mut scratch, &mut out)?;
        Ok(out)
    }

    /// Accumulated accounting since construction / last reset.
    fn stats(&self) -> BackendStats;

    /// Zero the accounting counters.
    fn reset_stats(&mut self);

    /// Set the matmul worker-thread count (0 = process default,
    /// [`crate::parallel::default_threads`]). Purely a scheduling knob:
    /// results are bit-identical for every value. The default impl is a
    /// no-op for backends with nothing to parallelize.
    fn set_threads(&mut self, _threads: usize) {}

    /// The configured worker-thread count (0 = process default) —
    /// what [`set_threads`](Self::set_threads) last stored. Helpers
    /// that parallelize *around* a backend ([`project_params`]) honor
    /// this bound too.
    fn threads(&self) -> usize {
        0
    }

    /// Convenience one-shot: stage + multiply. Prefer pre-staging on
    /// hot paths — this restages the weights every call.
    fn matmul_dense(&mut self, x: &Tensor, w: &Tensor) -> Result<Tensor> {
        let staged = self.stage_weights(w)?;
        self.matmul(x, &staged)
    }
}

/// Validate the common matmul operand contract; returns (M, N).
pub(crate) fn check_matmul(
    who: &str,
    x: &Tensor,
    w: &StagedWeights,
) -> Result<(usize, usize)> {
    if x.shape().len() != 2 {
        bail!("{who} matmul wants a 2-D activation, got {:?}", x.shape());
    }
    if x.shape()[1] != w.k() {
        bail!(
            "{who} matmul: reduction mismatch {} vs staged {}",
            x.shape()[1],
            w.k()
        );
    }
    Ok((x.shape()[0], w.rows()))
}

/// Validate a 2-D weight operand; returns (N, K).
pub(crate) fn check_weights(who: &str, w: &Tensor) -> Result<(usize, usize)> {
    if w.shape().len() != 2 {
        bail!("{who} stage_weights wants a 2-D matrix, got {:?}", w.shape());
    }
    Ok((w.shape()[0], w.shape()[1]))
}

/// Selector for the shipped backends (CLI `--backend`, sweep grids,
/// worker configs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Float32,
    Abfp,
    Fixed,
    Bfp,
}

impl BackendKind {
    /// Every shipped backend, in report order.
    pub const ALL: [BackendKind; 4] = [
        BackendKind::Float32,
        BackendKind::Abfp,
        BackendKind::Fixed,
        BackendKind::Bfp,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BackendKind::Float32 => "float32",
            BackendKind::Abfp => "abfp",
            BackendKind::Fixed => "fixed",
            BackendKind::Bfp => "bfp",
        }
    }

    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "float32" | "f32" | "float" => Ok(BackendKind::Float32),
            "abfp" => Ok(BackendKind::Abfp),
            "fixed" | "int" | "int8" => Ok(BackendKind::Fixed),
            "bfp" | "bfp-static" | "hbfp" => Ok(BackendKind::Bfp),
            other => bail!("unknown backend {other:?}; expected float32|abfp|fixed|bfp"),
        }
    }

    /// Parse a comma-separated selector; `all` expands to every backend.
    pub fn parse_list(s: &str) -> Result<Vec<BackendKind>> {
        if s.trim().eq_ignore_ascii_case("all") {
            return Ok(Self::ALL.to_vec());
        }
        s.split(',')
            .filter(|p| !p.trim().is_empty())
            .map(Self::parse)
            .collect()
    }

    /// Build a simulator instance. `cfg` supplies the device geometry:
    /// ABFP uses all of it, `bfp` uses tile width + operand bits,
    /// `fixed` uses operand bits only, `float32` ignores it. `seed`
    /// drives the ABFP ADC noise stream (unused elsewhere).
    pub fn build(self, cfg: DeviceConfig, seed: u64) -> Box<dyn NumericBackend> {
        match self {
            BackendKind::Float32 => Box::new(Float32Backend::new()),
            BackendKind::Abfp => Box::new(AbfpBackend::new(cfg, seed)),
            BackendKind::Fixed => Box::new(FixedPointBackend::new(cfg.bits_w, cfg.bits_x)),
            BackendKind::Bfp => Box::new(BfpStaticBackend::new(cfg.n, cfg.bits_w, cfg.bits_x)),
        }
    }

    /// True when the tile width in [`DeviceConfig`] affects this
    /// backend's numerics (used to prune degenerate sweep cells).
    pub fn uses_tiles(self) -> bool {
        matches!(self, BackendKind::Abfp | BackendKind::Bfp)
    }

    /// True when the analog gain in [`DeviceConfig`] affects this
    /// backend's numerics (only the ABFP analog path has gain).
    pub fn uses_gain(self) -> bool {
        matches!(self, BackendKind::Abfp)
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BackendKind> {
        Self::parse(s)
    }
}

/// Project parameter tensors onto a backend's representable grid: stage
/// once, dequantize back to FLOAT32. Rank >= 2 tensors are viewed as
/// (rows, last-dim) matrices — the device layout; rank-0/1 tensors
/// (biases, scalars) pass through unchanged. This is the
/// weight-residency approximation used when a backend has no dedicated
/// AOT artifact: weights live on the device in the backend's format,
/// activations stay FLOAT32.
///
/// Projection is noise-free staging, so it is a pure per-tensor
/// function — the tensors are projected in parallel with
/// deterministic, order-preserving results, bounded by the backend's
/// configured thread count (`set_threads`; 0 = process default).
pub fn project_params(backend: &dyn NumericBackend, params: &[Tensor]) -> Result<Vec<Tensor>> {
    crate::parallel::par_map(backend.threads(), params, |p| project_tensor(backend, p))
        .into_iter()
        .collect()
}

/// Project one tensor (see [`project_params`]).
pub fn project_tensor(backend: &dyn NumericBackend, p: &Tensor) -> Result<Tensor> {
    if p.shape().len() < 2 {
        return Ok(p.clone());
    }
    let cols = p.shape()[p.shape().len() - 1];
    let rows = p.len() / cols.max(1);
    let flat = p.clone().reshape(&[rows, cols])?;
    let staged = backend.stage_weights(&flat)?;
    staged.dequantize().reshape(p.shape())
}

/// Build the backend roster description (name + exact config) for
/// reports and manifests.
pub fn roster_json(kinds: &[BackendKind], cfg: DeviceConfig, seed: u64) -> Value {
    json::arr(
        kinds
            .iter()
            .map(|k| k.build(cfg, seed).config_json())
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in BackendKind::ALL {
            assert_eq!(BackendKind::parse(kind.name()).unwrap(), kind);
            assert_eq!(kind.name().parse::<BackendKind>().unwrap(), kind);
        }
        assert!(BackendKind::parse("mystery").is_err());
        assert_eq!(
            BackendKind::parse_list("all").unwrap(),
            BackendKind::ALL.to_vec()
        );
        assert_eq!(
            BackendKind::parse_list("float32,abfp").unwrap(),
            vec![BackendKind::Float32, BackendKind::Abfp]
        );
    }

    #[test]
    fn build_names_match_kinds() {
        let cfg = DeviceConfig::paper_default(32);
        for kind in BackendKind::ALL {
            let b = kind.build(cfg, 1);
            assert_eq!(b.name(), kind.name());
            // Every backend records its identity in the config json.
            assert!(b.config_json().to_string().contains(kind.name()));
        }
    }

    #[test]
    fn set_threads_roundtrip_on_every_backend() {
        // project_params bounds its fan-out by backend.threads(), so
        // the setter/getter pair must round-trip on every kind.
        let cfg = DeviceConfig::paper_default(8);
        for kind in BackendKind::ALL {
            let mut b = kind.build(cfg, 1);
            assert_eq!(b.threads(), 0, "{}", kind.name());
            b.set_threads(3);
            assert_eq!(b.threads(), 3, "{}", kind.name());
        }
    }

    #[test]
    fn staged_backend_mismatch_rejected() {
        let cfg = DeviceConfig::paper_default(8);
        let w = Tensor::full(&[4, 16], 0.5);
        let staged = Float32Backend::new().stage_weights(&w).unwrap();
        let mut abfp = AbfpBackend::new(cfg, 1);
        let x = Tensor::full(&[2, 16], 1.0);
        assert!(abfp.matmul(&x, &staged).is_err());
    }

    #[test]
    fn staged_tiles_dequantize_drops_padding() {
        // K = 5 at n = 4: second tile holds 1 real + 3 padded columns.
        let mut st = StagedTiles::with_capacity(1, 5, 4);
        st.scales.extend([2.0, 4.0]);
        st.q = vec![0.5, -0.25, 0.0, 1.0, 0.5, 0.0, 0.0, 0.0];
        let w = st.dequantize();
        assert_eq!(w.shape(), &[1, 5]);
        assert_eq!(w.data(), &[1.0, -0.5, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn project_params_preserves_shape_and_small_tensors() {
        let cfg = DeviceConfig::paper_default(8);
        let backend = BackendKind::Fixed.build(cfg, 1);
        let mut rng = Pcg64::seeded(3);
        let p3 = Tensor::new(&[2, 3, 8], rng.normal_vec(48)).unwrap();
        let bias = Tensor::from_vec(vec![0.1, 0.2, 0.3]);
        let out = project_params(backend.as_ref(), &[p3.clone(), bias.clone()]).unwrap();
        assert_eq!(out[0].shape(), p3.shape());
        assert_eq!(out[1], bias); // rank-1 passthrough
        // Projection moves values onto the grid but keeps them close.
        for (a, b) in out[0].data().iter().zip(p3.data()) {
            assert!((a - b).abs() < 0.1, "{a} vs {b}");
        }
    }
}
