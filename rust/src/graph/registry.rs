//! The model registry: one static record per Mini archetype.
//!
//! Single source of truth for model metadata that used to be scattered
//! across `models::paper_name`, the per-model matches in `main.rs`, and
//! the dataset encoding table in `data/`: paper name, per-example
//! input/target shapes, the graph head width, and the default device
//! tile. `crate::models` and the graph builders both read from here;
//! lookups return `Result` so a typo'd model name is an error with the
//! accepted roster, never a silent `"?"`.

use anyhow::{anyhow, Result};

/// Static metadata for one Mini archetype.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelMeta {
    /// Short archetype name (the CLI / manifest / dataset key).
    pub name: &'static str,
    /// The paper DNN this archetype stands in for (Table I).
    pub paper_name: &'static str,
    /// Per-example input shape (matches `data::Dataset::input_shape`).
    pub input_shape: &'static [usize],
    /// Per-example target shape (matches `data::Dataset::target_shape`).
    pub target_shape: &'static [usize],
    /// Output features of the model's graph head.
    pub out_elems: usize,
    /// Default analog tile width for this model's device plans.
    pub default_tile: usize,
}

impl ModelMeta {
    /// Flat input elements per example.
    pub fn in_elems(&self) -> usize {
        self.input_shape.iter().product()
    }
}

/// All six archetypes, in the paper's Table I order.
pub const REGISTRY: [ModelMeta; 6] = [
    ModelMeta {
        name: "cnn",
        paper_name: "ResNet50 (MiniCNN)",
        input_shape: &[16, 16, 3],
        target_shape: &[],
        out_elems: 10,
        default_tile: 128,
    },
    ModelMeta {
        name: "ssd",
        paper_name: "SSD-ResNet34 (MiniSSD)",
        input_shape: &[24, 24, 3],
        target_shape: &[5],
        out_elems: 5,
        default_tile: 128,
    },
    ModelMeta {
        name: "unet",
        paper_name: "3D U-Net (MiniUNet)",
        input_shape: &[16, 16, 1],
        target_shape: &[16, 16],
        out_elems: 256,
        default_tile: 128,
    },
    ModelMeta {
        name: "gru",
        paper_name: "RNN-T (MiniGRU)",
        input_shape: &[24],
        target_shape: &[],
        out_elems: 12,
        default_tile: 32,
    },
    ModelMeta {
        name: "bert",
        paper_name: "BERT-Large (MiniBERT)",
        input_shape: &[32],
        target_shape: &[2],
        out_elems: 64,
        default_tile: 128,
    },
    ModelMeta {
        name: "dlrm",
        paper_name: "DLRM (MiniDLRM)",
        input_shape: &[12],
        target_shape: &[],
        out_elems: 1,
        default_tile: 32,
    },
];

/// The archetype names in registry (paper Table I) order — derived
/// from [`REGISTRY`] at compile time, so the roster cannot drift.
pub const MODEL_NAMES: [&str; 6] = [
    REGISTRY[0].name,
    REGISTRY[1].name,
    REGISTRY[2].name,
    REGISTRY[3].name,
    REGISTRY[4].name,
    REGISTRY[5].name,
];

/// Look a model up by name; unknown names are an error carrying the
/// accepted roster (the old `paper_name` returned `"?"` silently).
pub fn meta(model: &str) -> Result<&'static ModelMeta> {
    REGISTRY
        .iter()
        .find(|m| m.name == model)
        .ok_or_else(|| anyhow!("unknown model {model:?}; expected one of {MODEL_NAMES:?}"))
}

/// The tile width a plan's `n = 0` ("auto") sentinel resolves to for
/// `model` — the registry default, or the paper tile (128) for
/// hand-built graphs outside the registry. The executor and the
/// planner's probes/cost model must agree on this substitution, so it
/// lives here once.
pub fn default_tile(model: &str) -> usize {
    meta(model).map(|m| m.default_tile).unwrap_or(128)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset_for;

    #[test]
    fn lookup_and_unknown() {
        assert_eq!(meta("cnn").unwrap().paper_name, "ResNet50 (MiniCNN)");
        let err = meta("nope").unwrap_err();
        assert!(err.to_string().contains("cnn"), "{err}");
    }

    #[test]
    fn registry_shapes_match_the_datasets() {
        // The registry is the single source of truth, so it must agree
        // with what the data generators actually emit per example.
        for m in &REGISTRY {
            let ds = dataset_for(m.name).unwrap();
            assert_eq!(ds.input_shape(), m.input_shape.to_vec(), "{}", m.name);
            assert_eq!(ds.target_shape(), m.target_shape.to_vec(), "{}", m.name);
            assert!(m.in_elems() > 0 && m.out_elems > 0);
            assert!(m.default_tile >= 1);
        }
    }
}
