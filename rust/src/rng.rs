//! Deterministic pseudo-random numbers: PCG64 plus the distributions the
//! reproduction needs (uniform, normal, Laplace, categorical),
//! Fisher–Yates shuffling, and the counter-based [`CounterRng`] used by
//! the ADC noise engine.
//!
//! Substrate note: no `rand` crate is available offline, and determinism
//! across runs matters for EXPERIMENTS.md, so this is implemented from
//! scratch. PCG-XSL-RR 128/64 follows O'Neill (2014); the counter-based
//! generator chains SplitMix64 finalizers (Steele et al. 2014), the same
//! construction family as Philox/Threefry counter RNGs.

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed and a stream id (must be odd-ized).
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.step();
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.step();
        rng
    }

    /// Convenience constructor on stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n) via Lemire's multiply-shift.
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// deterministic across platforms).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Standard Laplace (b = 1) via inverse CDF.
    pub fn laplace(&mut self) -> f32 {
        let u = self.next_f64() - 0.5;
        (-u.signum() * (1.0 - 2.0 * u.abs()).ln()) as f32
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.uniform(lo, hi)).collect()
    }

    /// Derive an independent generator (for per-worker streams).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64(), self.next_u64())
    }
}

const GOLDEN: u64 = 0x9e37_79b9_7f4a_7c15;

/// One SplitMix64 step: add the golden-gamma increment, then the
/// xor-shift-multiply finalizer (Steele, Lea & Flood 2014).
#[inline]
fn splitmix(mut z: u64) -> u64 {
    z = z.wrapping_add(GOLDEN);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Counter-based (stateless) RNG: a pure hash from `(seed, stream,
/// coordinates)` to a uniform draw.
///
/// Unlike [`Pcg64`], which yields a *sequence* (each draw depends on how
/// many came before it), `CounterRng` yields a *field*: the draw at
/// coordinates `(a, b, c)` is a pure function of the key and the
/// coordinates. That is what makes the ABFP device's ADC noise
/// schedule-independent — the noise injected at output `(row, col)`,
/// tile `ti` is the same whether the matmul runs on 1 thread or 64, in
/// one batch or split across calls (`tests/determinism.rs`).
///
/// Construction: chained SplitMix64 finalizers over the coordinates,
/// each coordinate pre-whitened by a golden-ratio multiply so that
/// permuted coordinates land on different draws. Statistical quality is
/// checked by the moment/uniformity tests below.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CounterRng {
    key: u64,
}

impl CounterRng {
    /// Key the field from a seed and a stream id (stream separates
    /// independent consumers with the same user seed).
    pub fn new(seed: u64, stream: u64) -> CounterRng {
        CounterRng {
            key: splitmix(splitmix(stream) ^ seed),
        }
    }

    /// Raw 64-bit hash at coordinates `(a, b, c)`.
    #[inline]
    pub fn at(&self, a: u64, b: u64, c: u64) -> u64 {
        let mut h = self.key;
        h = splitmix(h ^ a.wrapping_mul(GOLDEN));
        h = splitmix(h ^ b.wrapping_mul(GOLDEN));
        h = splitmix(h ^ c.wrapping_mul(GOLDEN));
        h
    }

    /// Uniform in [0, 1) with 53-bit resolution at `(a, b, c)` (same
    /// float mapping as [`Pcg64::next_f64`]).
    #[inline]
    pub fn f64_at(&self, a: u64, b: u64, c: u64) -> f64 {
        (self.at(a, b, c) >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi) at `(a, b, c)` (same mapping as
    /// [`Pcg64::uniform`]).
    #[inline]
    pub fn uniform_at(&self, a: u64, b: u64, c: u64, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64_at(a, b, c) as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut rng = Pcg64::seeded(7);
        let v = rng.uniform_vec(20_000, -1.0, 1.0);
        assert!(v.iter().all(|x| (-1.0..1.0).contains(x)));
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(11);
        let v = rng.normal_vec(50_000);
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let var: f64 =
            v.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn laplace_moments() {
        // Standard Laplace: mean 0, variance 2b^2 = 2.
        let mut rng = Pcg64::seeded(13);
        let v: Vec<f64> = (0..50_000).map(|_| rng.laplace() as f64).collect();
        let mean: f64 = v.iter().sum::<f64>() / v.len() as f64;
        let var: f64 = v.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / v.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 2.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut rng = Pcg64::seeded(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
        assert_ne!(v, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn categorical_distribution() {
        let mut rng = Pcg64::seeded(9);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        let f2 = counts[2] as f64 / 30_000.0;
        assert!((f2 - 0.7).abs() < 0.02, "{counts:?}");
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Pcg64::seeded(21);
        let mut a = base.split();
        let mut b = base.split();
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn counter_rng_is_a_pure_function_of_coordinates() {
        let f = CounterRng::new(42, 7);
        let g = CounterRng::new(42, 7);
        // Same key + coordinates -> same draw, in any query order.
        assert_eq!(f.at(1, 2, 3), g.at(1, 2, 3));
        let forward: Vec<u64> = (0..100).map(|i| f.at(i, 0, 0)).collect();
        let backward: Vec<u64> = (0..100).rev().map(|i| f.at(i, 0, 0)).collect();
        assert_eq!(forward, backward.into_iter().rev().collect::<Vec<_>>());
        // Different seed or stream -> different field.
        assert_ne!(CounterRng::new(43, 7).at(1, 2, 3), f.at(1, 2, 3));
        assert_ne!(CounterRng::new(42, 8).at(1, 2, 3), f.at(1, 2, 3));
    }

    #[test]
    fn counter_rng_coordinates_are_not_interchangeable() {
        let f = CounterRng::new(9, 9);
        assert_ne!(f.at(1, 0, 0), f.at(0, 1, 0));
        assert_ne!(f.at(0, 1, 0), f.at(0, 0, 1));
        assert_ne!(f.at(5, 7, 0), f.at(7, 5, 0));
    }

    #[test]
    fn counter_rng_uniform_moments() {
        // Draws over a (row, col, tile) lattice — exactly the access
        // pattern of the ADC noise engine — must look iid uniform.
        let f = CounterRng::new(0xadc, 0x0abf_9000);
        let mut vals = Vec::new();
        for r in 0..40u64 {
            for c in 0..40u64 {
                for t in 0..4u64 {
                    vals.push(f.f64_at(r, c, t));
                }
            }
        }
        let n = vals.len() as f64;
        let mean: f64 = vals.iter().sum::<f64>() / n;
        let var: f64 = vals.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / n;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var {var}");
        assert!(vals.iter().all(|v| (0.0..1.0).contains(v)));
        // Lag-1 correlation along the row axis (the axis parallel
        // workers split on) must vanish.
        let lag: f64 = vals
            .windows(2)
            .map(|w| (w[0] - mean) * (w[1] - mean))
            .sum::<f64>()
            / (n - 1.0);
        assert!(lag.abs() / var < 0.05, "lag-1 corr {}", lag / var);
    }

    #[test]
    fn counter_rng_uniform_at_range() {
        let f = CounterRng::new(3, 4);
        for i in 0..1000u64 {
            let v = f.uniform_at(i, 1, 2, -1.0, 1.0);
            assert!((-1.0..1.0).contains(&v));
        }
    }
}
