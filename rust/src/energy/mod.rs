//! The ADC energy model of Rekhi et al. [6] and the paper's section VI
//! energy analysis.
//!
//! Model: ADC energy per conversion scales as `E ∝ 2^b` with the output
//! bit count `b` (mixed-signal converter scaling); analog gain `G`
//! multiplies signal power, so energy scales linearly in `G`; the analog
//! MVM array computes `n` MACs per conversion, so throughput scales with
//! the tile width. The paper's headline: ABFP at (n=128, G=8, 8 output
//! bits) vs Rekhi's optimal (n=8, 12.5 bits) saves
//! `2^(12.5-8) / 8 ≈ 2.8x` ADC energy and runs `128/8 = 16x` more MACs
//! per cycle.

/// One analog design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignPoint {
    /// Tile width (dot-product length per conversion).
    pub n: usize,
    /// ADC output bits (may be fractional: effective bits).
    pub adc_bits: f64,
    /// Analog gain.
    pub gain: f64,
}

impl DesignPoint {
    /// The paper's ABFP operating point for ResNet50 (section VI).
    pub fn abfp_resnet50() -> DesignPoint {
        DesignPoint {
            n: 128,
            adc_bits: 8.0,
            gain: 8.0,
        }
    }

    /// Rekhi et al.'s optimal for ResNet50 at <1% loss: 12.5 effective
    /// bits at tile width 8, unit gain.
    pub fn rekhi_optimal() -> DesignPoint {
        DesignPoint {
            n: 8,
            adc_bits: 12.5,
            gain: 1.0,
        }
    }

    /// Relative ADC energy per conversion: `2^bits * gain` (arbitrary
    /// units; only ratios are meaningful).
    pub fn adc_energy_per_conversion(&self) -> f64 {
        self.adc_bits.exp2() * self.gain
    }

    /// MACs performed per ADC conversion = tile width.
    pub fn macs_per_conversion(&self) -> f64 {
        self.n as f64
    }

    /// Relative ADC energy *per MAC* — the figure of merit.
    pub fn adc_energy_per_mac(&self) -> f64 {
        self.adc_energy_per_conversion() / self.macs_per_conversion()
    }

    /// MACs per clock on an `n x n` MVM array (footnote 4).
    pub fn macs_per_cycle(&self) -> f64 {
        (self.n * self.n) as f64
    }
}

/// Energy comparison of two design points (section VI arithmetic).
#[derive(Debug, Clone, Copy)]
pub struct Comparison {
    /// ADC-bit energy saving factor `2^(b_ref - b_new)`.
    pub bit_saving: f64,
    /// Energy increase from gain.
    pub gain_cost: f64,
    /// Net per-conversion energy saving.
    pub net_conversion_saving: f64,
    /// Per-MAC energy saving (includes tile-width amortization).
    pub per_mac_saving: f64,
    /// Throughput factor in MACs per cycle.
    pub throughput_factor: f64,
}

/// Compare `new` against `reference` (positive = `new` wins).
pub fn compare(new: DesignPoint, reference: DesignPoint) -> Comparison {
    let bit_saving = (reference.adc_bits - new.adc_bits).exp2();
    let gain_cost = new.gain / reference.gain;
    Comparison {
        bit_saving,
        gain_cost,
        net_conversion_saving: bit_saving / gain_cost,
        per_mac_saving: reference.adc_energy_per_mac() / new.adc_energy_per_mac(),
        throughput_factor: new.macs_per_cycle() / reference.macs_per_cycle(),
    }
}

/// ADC bits needed to capture a full `n`-wide dot product of
/// `b_w`/`b_x`-bit operands: `b_w + b_x + log2(n) - 1` (section III-B).
pub fn full_precision_bits(b_w: u32, b_x: u32, n: usize) -> f64 {
    b_w as f64 + b_x as f64 + (n as f64).log2() - 1.0
}

use crate::abfp::DeviceConfig;
use crate::backend::BackendKind;
use crate::numerics::num_tiles;

/// Relative energy of one analog MAC (the unit everything else is
/// priced against; arbitrary units, only ratios are meaningful).
pub const ANALOG_MAC_ENERGY: f64 = 1.0;

/// Relative energy of one FLOAT32 digital MAC: a 32x32-bit multiplier
/// under the same bits-product scaling as [`digital_mac_energy`].
pub const FLOAT32_MAC_ENERGY: f64 = 32.0 * 32.0;

/// Relative energy of one digital MAC on `b_w` x `b_x`-bit operands —
/// multiplier area/energy scales with the product of operand widths.
pub fn digital_mac_energy(b_w: u32, b_x: u32) -> f64 {
    b_w as f64 * b_x as f64
}

/// Relative DAC energy per conversion: `2^bits` (same mixed-signal
/// converter scaling as the ADC model, without the gain term — the DAC
/// drives the array input, gain applies on the output side).
pub fn dac_energy_per_conversion(bits: u32) -> f64 {
    (bits as f64).exp2()
}

/// Energy decomposition of one `(out, in)` matmul on one example —
/// MAC work plus the converter traffic around it. This is what plan
/// pricing sums per layer: conversion counts make tile width a real
/// cost lever (more tiles = more ADC samples per output), not just a
/// numerics knob.
#[derive(Debug, Clone, Copy, Default)]
pub struct MatmulEnergy {
    /// Multiply-accumulates (`out * in`).
    pub macs: u64,
    /// Total MAC energy (analog or digital per the backend).
    pub mac_energy: f64,
    /// Input-side conversions (activation DAC writes; 0 for float32).
    pub dac_conversions: u64,
    pub dac_energy: f64,
    /// Output-side conversions (ADC samples per output per tile for
    /// ABFP; one quantized output per element for the digital formats).
    pub adc_conversions: u64,
    pub adc_energy: f64,
}

impl MatmulEnergy {
    /// Total relative energy of the matmul.
    pub fn total(&self) -> f64 {
        self.mac_energy + self.dac_energy + self.adc_energy
    }
}

/// Price one `(out_features, in_features)` matmul on one example under
/// `kind` at `device`. The model, per backend:
///
/// * `float32` — `out*in` digital MACs at 32x32-bit energy; no
///   converters on the path.
/// * `abfp`    — analog MACs at unit energy; `in` DAC conversions at
///   `2^bits_x`; `out * tiles(in, n)` ADC conversions at
///   `2^bits_y * gain` (the Rekhi scaling of
///   [`DesignPoint::adc_energy_per_conversion`]) — tile width enters
///   the price directly.
/// * `fixed` / `bfp` — digital MACs at `b_w*b_x`; `in` input
///   quantizations and `out` output quantizations at `2^bits_x` each
///   (these formats quantize each output once digitally — no per-tile
///   ADC, so tiling costs nothing extra).
pub fn matmul_energy(
    kind: BackendKind,
    device: &DeviceConfig,
    out_features: usize,
    in_features: usize,
) -> MatmulEnergy {
    let macs = (out_features * in_features) as u64;
    match kind {
        BackendKind::Float32 => MatmulEnergy {
            macs,
            mac_energy: macs as f64 * FLOAT32_MAC_ENERGY,
            ..MatmulEnergy::default()
        },
        BackendKind::Abfp => {
            let tiles = num_tiles(in_features, device.n.max(1));
            let dac = in_features as u64;
            let adc = (out_features * tiles) as u64;
            let point = DesignPoint {
                n: device.n.max(1),
                adc_bits: device.bits_y as f64,
                gain: device.gain as f64,
            };
            MatmulEnergy {
                macs,
                mac_energy: macs as f64 * ANALOG_MAC_ENERGY,
                dac_conversions: dac,
                dac_energy: dac as f64 * dac_energy_per_conversion(device.bits_x),
                adc_conversions: adc,
                adc_energy: adc as f64 * point.adc_energy_per_conversion(),
            }
        }
        BackendKind::Fixed | BackendKind::Bfp => {
            let dac = in_features as u64;
            let adc = out_features as u64;
            let per_conv = dac_energy_per_conversion(device.bits_x);
            MatmulEnergy {
                macs,
                mac_energy: macs as f64 * digital_mac_energy(device.bits_w, device.bits_x),
                dac_conversions: dac,
                dac_energy: dac as f64 * per_conv,
                adc_conversions: adc,
                adc_energy: adc as f64 * per_conv,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_numbers() {
        let cmp = compare(DesignPoint::abfp_resnet50(), DesignPoint::rekhi_optimal());
        // "The energy savings from reducing the ADC bits is 2^(12.5-8) ~ 23x"
        assert!((cmp.bit_saving - 22.627).abs() < 0.01, "{cmp:?}");
        // "...the energy increase with a gain of 8 is a factor of 8x"
        assert_eq!(cmp.gain_cost, 8.0);
        // "...overall our method reduces energy by a factor of ~2.8"
        assert!((cmp.net_conversion_saving - 2.8284).abs() < 0.01, "{cmp:?}");
        // "...executes 16x more multiply-accumulate operations per clock
        // cycle" — per MVM *row*; as full n x n arrays it is 16^2.
        assert!((cmp.throughput_factor - 256.0).abs() < 1e-9);
        let row_factor = DesignPoint::abfp_resnet50().n as f64
            / DesignPoint::rekhi_optimal().n as f64;
        assert_eq!(row_factor, 16.0);
    }

    #[test]
    fn per_mac_saving_includes_amortization() {
        let cmp = compare(DesignPoint::abfp_resnet50(), DesignPoint::rekhi_optimal());
        // Per-MAC: 2.83x conversion saving x 16x amortization.
        assert!((cmp.per_mac_saving - 2.8284 * 16.0).abs() < 0.1, "{cmp:?}");
    }

    #[test]
    fn energy_monotone_in_bits_and_gain() {
        let base = DesignPoint {
            n: 8,
            adc_bits: 8.0,
            gain: 1.0,
        };
        let more_bits = DesignPoint {
            adc_bits: 10.0,
            ..base
        };
        let more_gain = DesignPoint { gain: 4.0, ..base };
        assert!(more_bits.adc_energy_per_conversion() > base.adc_energy_per_conversion());
        assert!(more_gain.adc_energy_per_conversion() > base.adc_energy_per_conversion());
    }

    #[test]
    fn full_precision_bits_example() {
        // Paper: b_w = b_x = 8, n = 128 -> ~22 bits.
        assert!((full_precision_bits(8, 8, 128) - 22.0).abs() < 1e-9);
    }

    #[test]
    fn matmul_energy_monotone_in_bits() {
        // More converter / operand bits never makes a matmul cheaper.
        let lo = DeviceConfig::new(32, (6, 6, 6), 2.0, 0.5);
        let hi = DeviceConfig::new(32, (8, 8, 8), 2.0, 0.5);
        for kind in BackendKind::ALL {
            let a = matmul_energy(kind, &lo, 96, 96).total();
            let b = matmul_energy(kind, &hi, 96, 96).total();
            assert!(b >= a, "{kind:?}: {b} < {a}");
        }
        // ...and strictly more for every converter-bearing backend.
        for kind in [BackendKind::Abfp, BackendKind::Bfp, BackendKind::Fixed] {
            let a = matmul_energy(kind, &lo, 96, 96).total();
            let b = matmul_energy(kind, &hi, 96, 96).total();
            assert!(b > a, "{kind:?}: {b} <= {a}");
        }
    }

    #[test]
    fn matmul_energy_monotone_in_tiles() {
        // Narrower tiles => more tiles. ABFP pays one ADC sample per
        // output per tile, so its cost strictly rises; the digital
        // formats quantize outputs once, so their cost is flat.
        let wide = DeviceConfig::new(64, (8, 8, 8), 2.0, 0.5);
        let narrow = DeviceConfig::new(16, (8, 8, 8), 2.0, 0.5);
        let a = matmul_energy(BackendKind::Abfp, &wide, 96, 96);
        let b = matmul_energy(BackendKind::Abfp, &narrow, 96, 96);
        assert!(b.adc_conversions > a.adc_conversions);
        assert!(b.total() > a.total(), "{} <= {}", b.total(), a.total());
        for kind in [BackendKind::Float32, BackendKind::Bfp, BackendKind::Fixed] {
            let a = matmul_energy(kind, &wide, 96, 96).total();
            let b = matmul_energy(kind, &narrow, 96, 96).total();
            assert!(b >= a, "{kind:?}: {b} < {a}");
            assert_eq!(b, a, "{kind:?} should not pay for tiling");
        }
    }

    #[test]
    fn matmul_energy_orders_the_formats() {
        // gru fc2 shape: float32 is by far the most expensive, the
        // digital reduced-precision formats next, ABFP cheapest per MAC.
        let d = DeviceConfig::new(32, (8, 8, 8), 2.0, 0.5);
        let f = matmul_energy(BackendKind::Float32, &d, 96, 96);
        let x = matmul_energy(BackendKind::Fixed, &d, 96, 96);
        let a = matmul_energy(BackendKind::Abfp, &d, 96, 96);
        assert_eq!(f.macs, 96 * 96);
        assert_eq!(f.dac_conversions + f.adc_conversions, 0);
        assert!(f.total() > x.total());
        assert!(x.total() > a.total());
        // ABFP decomposition: 96*96 MACs at 1.0, 96 DACs at 2^8,
        // 96 outputs * 3 tiles ADCs at 2^8 * 2.
        assert_eq!(a.adc_conversions, 96 * 3);
        let expect = 96.0 * 96.0 + 96.0 * 256.0 + (96.0 * 3.0) * 256.0 * 2.0;
        assert!((a.total() - expect).abs() < 1e-6, "{}", a.total());
    }
}
