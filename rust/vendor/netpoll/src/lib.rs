//! netpoll — the one readiness syscall the abfp serving core needs,
//! vendored so the main crate can keep `#![forbid(unsafe_code)]`.
//!
//! The event loop in `abfp::coordinator::http` multiplexes thousands of
//! nonblocking sockets over a small fixed thread pool. The only piece
//! of that which std cannot express safely is "sleep until one of these
//! file descriptors is ready" — classic `poll(2)`. This crate confines
//! that single FFI call (plus a `setrlimit` helper the soak test uses
//! to open >1024 sockets) behind a safe [`Poller`] API:
//!
//! ```no_run
//! use netpoll::{Poller, READABLE, WRITABLE};
//! let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
//! let mut poller = Poller::new();
//! loop {
//!     poller.clear();
//!     let slot = poller.register(&listener, READABLE);
//!     poller.wait(Some(std::time::Duration::from_millis(50))).unwrap();
//!     if poller.readable(slot) { /* accept until WouldBlock */ }
//! }
//! ```
//!
//! The registration set is rebuilt every iteration (`clear` +
//! `register`), level-triggered like `poll(2)` itself — no slab, no
//! epoll-style ownership, and the backing `Vec` is reused so a steady
//! loop allocates nothing once warm.
//!
//! On non-unix targets (no `poll`), [`Poller::wait`] degrades to a
//! bounded sleep that reports every registered source ready: the caller
//! already treats readiness as a hint (nonblocking ops return
//! `WouldBlock` when there is nothing to do), so the loop stays correct
//! and merely burns a few wakeups per second — the documented portable
//! sleep-backoff fallback.

use std::io;
use std::time::Duration;

#[cfg(unix)]
use std::os::unix::io::AsRawFd;

/// Interest/readiness bit: the source has bytes to read (or a pending
/// accept, or an error/hangup the next read will surface).
pub const READABLE: u8 = 0b01;
/// Interest/readiness bit: the source can accept writes (or has an
/// error/hangup the next write will surface).
pub const WRITABLE: u8 = 0b10;

#[cfg(unix)]
mod sys {
    use std::io;

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    /// `struct pollfd` — identical layout on Linux and the BSDs.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    #[cfg(target_os = "linux")]
    type NFds = std::ffi::c_ulong;
    #[cfg(not(target_os = "linux"))]
    type NFds = std::ffi::c_uint;

    extern "C" {
        fn poll(
            fds: *mut PollFd,
            nfds: NFds,
            timeout: std::ffi::c_int,
        ) -> std::ffi::c_int;
    }

    /// Blocking `poll(2)` over `fds` with an EINTR retry loop.
    /// `timeout_ms < 0` blocks indefinitely, `0` polls.
    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> io::Result<usize> {
        loop {
            // SAFETY: `fds` is a valid, exclusively borrowed slice of
            // `#[repr(C)]` pollfd records for the duration of the call,
            // and the length is passed alongside the pointer.
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NFds, timeout_ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

/// A reusable `poll(2)` registration set. Rebuild it each loop
/// iteration with [`Poller::clear`] + [`Poller::register`], then
/// [`Poller::wait`]; readiness is read back per returned slot index.
#[derive(Default)]
pub struct Poller {
    #[cfg(unix)]
    fds: Vec<sys::PollFd>,
    /// Fallback bookkeeping: `(interest, ready)` per slot.
    #[cfg(not(unix))]
    fds: Vec<(u8, u8)>,
}

impl Poller {
    pub fn new() -> Poller {
        Poller::default()
    }

    /// Drop every registration, keeping the backing allocation.
    pub fn clear(&mut self) {
        self.fds.clear();
    }

    /// Number of registered sources.
    pub fn len(&self) -> usize {
        self.fds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.fds.is_empty()
    }

    /// Register a socket (anything `AsRawFd` on unix) with an interest
    /// mask ([`READABLE`] | [`WRITABLE`]). Returns the slot index used
    /// to read readiness back after [`Poller::wait`].
    #[cfg(unix)]
    pub fn register<S: AsRawFd>(&mut self, src: &S, interest: u8) -> usize {
        let mut events = 0i16;
        if interest & READABLE != 0 {
            events |= sys::POLLIN;
        }
        if interest & WRITABLE != 0 {
            events |= sys::POLLOUT;
        }
        self.fds.push(sys::PollFd {
            fd: src.as_raw_fd(),
            events,
            revents: 0,
        });
        self.fds.len() - 1
    }

    /// Fallback registration: no fd is captured; [`Poller::wait`]
    /// reports the slot ready per its interest after a bounded sleep.
    #[cfg(not(unix))]
    pub fn register<S>(&mut self, _src: &S, interest: u8) -> usize {
        self.fds.push((interest, 0));
        self.fds.len() - 1
    }

    /// Wait until at least one registered source is ready or `timeout`
    /// elapses (`None` = wait indefinitely). Returns how many sources
    /// reported readiness.
    #[cfg(unix)]
    pub fn wait(&mut self, timeout: Option<Duration>) -> io::Result<usize> {
        let timeout_ms: i32 = match timeout {
            None => -1,
            // Round up so a nonzero timeout can't spin at 0 ms; clamp
            // to i32 (poll's interface) — ~24 days is "indefinitely".
            Some(t) => t.as_millis().max(1).min(i32::MAX as u128) as i32,
        };
        sys::poll_fds(&mut self.fds, timeout_ms)
    }

    /// Portable fallback: bounded sleep, then report everything ready.
    #[cfg(not(unix))]
    pub fn wait(&mut self, timeout: Option<Duration>) -> io::Result<usize> {
        let nap = timeout.unwrap_or(Duration::from_millis(10)).min(Duration::from_millis(10));
        std::thread::sleep(nap);
        for slot in self.fds.iter_mut() {
            slot.1 = slot.0;
        }
        Ok(self.fds.len())
    }

    /// Did `slot` report readable? Errors/hangups count as readable —
    /// the caller's next nonblocking read surfaces the real error.
    pub fn readable(&self, slot: usize) -> bool {
        self.ready(slot, READABLE)
    }

    /// Did `slot` report writable? Errors/hangups count as writable —
    /// the caller's next nonblocking write surfaces the real error.
    pub fn writable(&self, slot: usize) -> bool {
        self.ready(slot, WRITABLE)
    }

    #[cfg(unix)]
    fn ready(&self, slot: usize, interest: u8) -> bool {
        let Some(fd) = self.fds.get(slot) else {
            return false;
        };
        let err = sys::POLLERR | sys::POLLHUP | sys::POLLNVAL;
        let want = match interest {
            READABLE => sys::POLLIN | err,
            _ => sys::POLLOUT | err,
        };
        fd.revents & want != 0
    }

    #[cfg(not(unix))]
    fn ready(&self, slot: usize, interest: u8) -> bool {
        self.fds.get(slot).map(|s| s.1 & interest != 0).unwrap_or(false)
    }
}

#[cfg(any(
    all(target_os = "linux", target_pointer_width = "64"),
    target_os = "macos"
))]
mod rlimit {
    use std::io;

    /// `struct rlimit` with 64-bit `rlim_t` (glibc/musl on 64-bit
    /// Linux, always on macOS).
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(target_os = "macos")]
    const RLIMIT_NOFILE: i32 = 8;

    extern "C" {
        fn getrlimit(resource: std::ffi::c_int, rlim: *mut RLimit) -> std::ffi::c_int;
        fn setrlimit(resource: std::ffi::c_int, rlim: *const RLimit) -> std::ffi::c_int;
    }

    pub fn raise_nofile(want: u64) -> io::Result<u64> {
        let mut lim = RLimit { cur: 0, max: 0 };
        // SAFETY: `lim` is a valid exclusive `#[repr(C)]` out-pointer.
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
            return Err(io::Error::last_os_error());
        }
        if lim.cur >= want {
            return Ok(lim.cur);
        }
        lim.cur = want.min(lim.max);
        // SAFETY: `lim` is a valid `#[repr(C)]` record for the call.
        if unsafe { setrlimit(RLIMIT_NOFILE, &lim) } != 0 {
            return Err(io::Error::last_os_error());
        }
        Ok(lim.cur)
    }
}

/// Raise the soft `RLIMIT_NOFILE` toward `want` (capped at the hard
/// limit) and return the resulting soft limit. The ≥1024-connection
/// soak test calls this; unsupported targets report
/// `ErrorKind::Unsupported` and the caller scales its load down.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    #[cfg(any(
        all(target_os = "linux", target_pointer_width = "64"),
        target_os = "macos"
    ))]
    {
        rlimit::raise_nofile(want)
    }
    #[cfg(not(any(
        all(target_os = "linux", target_pointer_width = "64"),
        target_os = "macos"
    )))]
    {
        let _ = want;
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "raise_nofile_limit: unsupported target",
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::{TcpListener, TcpStream, UdpSocket};
    use std::time::Instant;

    #[test]
    fn udp_readability_tracks_datagrams() {
        let rx = UdpSocket::bind("127.0.0.1:0").unwrap();
        rx.set_nonblocking(true).unwrap();
        let tx = UdpSocket::bind("127.0.0.1:0").unwrap();
        tx.connect(rx.local_addr().unwrap()).unwrap();

        let mut p = Poller::new();
        let slot = p.register(&rx, READABLE);
        // Nothing sent: times out quickly without readiness (on unix).
        let t0 = Instant::now();
        p.wait(Some(Duration::from_millis(20))).unwrap();
        if cfg!(unix) {
            assert!(!p.readable(slot));
            assert!(t0.elapsed() >= Duration::from_millis(15));
        }

        tx.send(b"x").unwrap();
        p.clear();
        let slot = p.register(&rx, READABLE);
        let n = p.wait(Some(Duration::from_secs(2))).unwrap();
        assert!(n >= 1);
        assert!(p.readable(slot));
        let mut buf = [0u8; 8];
        assert_eq!(rx.recv(&mut buf).unwrap(), 1);
    }

    #[test]
    fn tcp_listener_and_stream_readiness() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        listener.set_nonblocking(true).unwrap();
        let addr = listener.local_addr().unwrap();

        let client = TcpStream::connect(addr).unwrap();
        let mut p = Poller::new();
        let lslot = p.register(&listener, READABLE);
        let n = p.wait(Some(Duration::from_secs(2))).unwrap();
        assert!(n >= 1 && p.readable(lslot), "pending accept not reported");
        let (server_side, _) = listener.accept().unwrap();

        // A fresh connected stream with an empty send buffer: writable.
        p.clear();
        let wslot = p.register(&server_side, WRITABLE);
        p.wait(Some(Duration::from_secs(2))).unwrap();
        assert!(p.writable(wslot));
        drop(client);
    }

    #[test]
    fn nofile_limit_is_raised_or_unsupported() {
        match raise_nofile_limit(1024) {
            Ok(cur) => assert!(cur >= 256, "soft NOFILE suspiciously low: {cur}"),
            Err(e) => assert_eq!(e.kind(), io::ErrorKind::Unsupported),
        }
    }
}
