//! Task-quality metrics for the six archetypes — the "Model metrics" of
//! Table II, computed by the coordinator from raw model outputs.
//!
//!   top1       — classification accuracy (ResNet50, RNN-T analogue)
//!   detection  — mean(correct-class x IoU) (the one-object mAP analogue)
//!   dice       — mean Dice over classes (3D U-Net's "mean accuracy")
//!   span_f1    — SQuAD-style token-overlap F1 (BERT)
//!   auc        — ROC AUC (DLRM)

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Compute the metric named in the manifest from model outputs + targets.
///
/// Output arity and batch emptiness are validated here with `bail!`
/// rather than indexed unchecked: the serving path maps metric errors to
/// HTTP 500s, so a model returning fewer outputs than its metric needs
/// (or an empty evaluation batch) must surface as an `Err`, never a
/// panic in the worker thread.
pub fn compute(metric: &str, outputs: &[Tensor], y: &Tensor) -> Result<f64> {
    let need = match metric {
        "detection" | "span_f1" => 2,
        "top1" | "dice" | "auc" => 1,
        other => bail!("unknown metric {other:?}"),
    };
    if outputs.len() < need {
        bail!(
            "metric {metric:?} needs {need} model output(s), got {}",
            outputs.len()
        );
    }
    match metric {
        "top1" => top1(&outputs[0], y),
        "detection" => detection(&outputs[0], &outputs[1], y),
        "dice" => dice(&outputs[0], y),
        "span_f1" => span_f1(&outputs[0], &outputs[1], y),
        "auc" => auc(&outputs[0], y),
        _ => unreachable!(),
    }
}

/// Argmax over the last axis of a (B, C) tensor. `total_cmp` keeps a
/// NaN logit from panicking the comparator (NaN compares greatest, so a
/// fully-NaN row deterministically picks its last column). Public so
/// the planner's divergence scorer can reuse the exact top-1 decision
/// rule instead of reimplementing it.
pub fn argmax_rows(t: &Tensor) -> Vec<usize> {
    let c = *t.shape().last().unwrap();
    t.data()
        .chunks(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0
        })
        .collect()
}

/// Top-1 accuracy: logits (B, C) vs labels (B,).
pub fn top1(logits: &Tensor, y: &Tensor) -> Result<f64> {
    if logits.is_empty() {
        bail!("top1: empty batch (no logits)");
    }
    if y.len() * logits.shape().last().copied().unwrap_or(0) != logits.len() {
        bail!(
            "top1: {} labels do not match logits shape {:?}",
            y.len(),
            logits.shape()
        );
    }
    let preds = argmax_rows(logits);
    let correct = preds
        .iter()
        .zip(y.data())
        .filter(|(&p, &t)| p == t as usize)
        .count();
    Ok(correct as f64 / preds.len() as f64)
}

/// Intersection-over-union of two (cx, cy, w, h) boxes.
pub fn iou(a: &[f32], b: &[f32]) -> f64 {
    let half = |v: &[f32]| {
        let (cx, cy, w, h) = (v[0] as f64, v[1] as f64, v[2] as f64, v[3] as f64);
        (cx - w / 2.0, cx + w / 2.0, cy - h / 2.0, cy + h / 2.0)
    };
    let (ax0, ax1, ay0, ay1) = half(a);
    let (bx0, bx1, by0, by1) = half(b);
    let ix = (ax1.min(bx1) - ax0.max(bx0)).max(0.0);
    let iy = (ay1.min(by1) - ay0.max(by0)).max(0.0);
    let inter = ix * iy;
    let area_a = (ax1 - ax0) * (ay1 - ay0);
    let area_b = (bx1 - bx0) * (by1 - by0);
    let union = area_a + area_b - inter;
    if union <= 0.0 {
        0.0
    } else {
        inter / union
    }
}

/// Detection score: mean over examples of (class correct ? IoU : 0) —
/// the single-object analogue of mAP.
pub fn detection(conf: &Tensor, boxes: &Tensor, y: &Tensor) -> Result<f64> {
    if conf.is_empty() {
        bail!("detection: empty batch (no confidences)");
    }
    let preds = argmax_rows(conf);
    let b = preds.len();
    if boxes.len() != b * 4 || y.len() != b * 5 {
        bail!(
            "detection: batch {b} wants boxes (B,4) and targets (B,5), \
             got {} and {} elements",
            boxes.len(),
            y.len()
        );
    }
    let mut total = 0.0f64;
    for i in 0..b {
        let target = &y.data()[i * 5..(i + 1) * 5];
        let pred_box = &boxes.data()[i * 4..(i + 1) * 4];
        if preds[i] == target[0] as usize {
            total += iou(pred_box, &target[1..5]);
        }
    }
    Ok(total / b as f64)
}

/// Mean Dice over {background, foreground}: logits (B, H, W, 2) vs mask
/// (B, H, W). This is the "mean accuracy" style metric of the 3D U-Net
/// row in Table II.
pub fn dice(logits: &Tensor, y: &Tensor) -> Result<f64> {
    let px = y.len();
    if px == 0 {
        bail!("dice: empty batch (no mask pixels)");
    }
    if logits.len() != px * 2 {
        bail!(
            "dice: {} mask pixels want {} logits (2 classes), got {}",
            px,
            px * 2,
            logits.len()
        );
    }
    let mut inter = [0.0f64; 2];
    let mut pred_n = [0.0f64; 2];
    let mut true_n = [0.0f64; 2];
    for i in 0..px {
        let fg = logits.data()[i * 2 + 1] > logits.data()[i * 2];
        let p = usize::from(fg);
        let t = y.data()[i];
        // A mask value outside {0, 1} would index true_n out of bounds —
        // the same worker-thread panic class the arity checks above
        // close off.
        if t != 0.0 && t != 1.0 {
            bail!("dice: mask value {t} at pixel {i} is not a binary label");
        }
        let t = t as usize;
        pred_n[p] += 1.0;
        true_n[t] += 1.0;
        if p == t {
            inter[p] += 1.0;
        }
    }
    let mut total = 0.0;
    for c in 0..2 {
        let denom = pred_n[c] + true_n[c];
        total += if denom == 0.0 {
            1.0
        } else {
            2.0 * inter[c] / denom
        };
    }
    Ok(total / 2.0)
}

/// SQuAD-style span F1: predicted span = (argmax start, argmax end),
/// token-overlap F1 against the gold span, averaged over examples.
pub fn span_f1(start_logits: &Tensor, end_logits: &Tensor, y: &Tensor) -> Result<f64> {
    if start_logits.is_empty() || end_logits.is_empty() {
        bail!("span_f1: empty batch (no logits)");
    }
    let s_pred = argmax_rows(start_logits);
    let e_pred = argmax_rows(end_logits);
    let b = s_pred.len();
    if e_pred.len() != b || y.len() != b * 2 {
        bail!(
            "span_f1: batch {b} wants matching end logits and gold spans \
             (B,2), got {} rows and {} target elements",
            e_pred.len(),
            y.len()
        );
    }
    let mut total = 0.0f64;
    for i in 0..b {
        let (ps, pe) = (s_pred[i], e_pred[i].max(s_pred[i]));
        let (ts, te) = (y.data()[i * 2] as usize, y.data()[i * 2 + 1] as usize);
        let inter = (pe.min(te) + 1).saturating_sub(ps.max(ts)) as f64;
        if inter > 0.0 {
            let p = inter / (pe - ps + 1) as f64;
            let r = inter / (te - ts + 1) as f64;
            total += 2.0 * p * r / (p + r);
        }
    }
    Ok(total / b as f64)
}

/// ROC AUC via the rank statistic (ties get midranks).
pub fn auc(scores: &Tensor, y: &Tensor) -> Result<f64> {
    let n = scores.len();
    if y.len() != n {
        bail!("auc: {} labels for {n} scores", y.len());
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores.data()[a].total_cmp(&scores.data()[b]));
    // Midrank assignment.
    let mut ranks = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores.data()[idx[j + 1]] == scores.data()[idx[i]] {
            j += 1;
        }
        let mid = (i + j) as f64 / 2.0 + 1.0;
        for k in i..=j {
            ranks[idx[k]] = mid;
        }
        i = j + 1;
    }
    let pos: f64 = y.data().iter().map(|&v| v as f64).sum();
    let neg = n as f64 - pos;
    if pos == 0.0 || neg == 0.0 {
        return Ok(0.5);
    }
    let rank_sum: f64 = (0..n)
        .filter(|&i| y.data()[i] == 1.0)
        .map(|i| ranks[i])
        .sum();
    Ok((rank_sum - pos * (pos + 1.0) / 2.0) / (pos * neg))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize], data: Vec<f32>) -> Tensor {
        Tensor::new(shape, data).unwrap()
    }

    #[test]
    fn top1_counts_matches() {
        let logits = t(&[3, 2], vec![1.0, 0.0, 0.0, 1.0, 2.0, 1.0]);
        let y = t(&[3], vec![0.0, 1.0, 1.0]);
        assert!((top1(&logits, &y).unwrap() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn iou_identical_and_disjoint() {
        let a = [0.5, 0.5, 0.2, 0.2];
        assert!((iou(&a, &a) - 1.0).abs() < 1e-9);
        let b = [0.9, 0.9, 0.1, 0.1];
        assert_eq!(iou(&a, &b), 0.0);
    }

    #[test]
    fn iou_half_overlap() {
        let a = [0.25, 0.5, 0.5, 1.0];
        let b = [0.5, 0.5, 0.5, 1.0];
        // Overlap width 0.25 of two 0.5-wide boxes: 0.25/(0.5+0.5-0.25).
        assert!((iou(&a, &b) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn detection_requires_class_match() {
        let conf = t(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let boxes = t(&[2, 4], vec![0.5, 0.5, 0.2, 0.2, 0.5, 0.5, 0.2, 0.2]);
        let y = t(
            &[2, 5],
            vec![0.0, 0.5, 0.5, 0.2, 0.2, 0.0, 0.5, 0.5, 0.2, 0.2],
        );
        // Example 0: class correct, perfect IoU; example 1: wrong class.
        assert!((detection(&conf, &boxes, &y).unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dice_perfect_and_inverted() {
        let logits = t(&[1, 2, 1, 2], vec![0.0, 1.0, 1.0, 0.0]);
        let y = t(&[1, 2, 1], vec![1.0, 0.0]);
        assert!((dice(&logits, &y).unwrap() - 1.0).abs() < 1e-12);
        let y_bad = t(&[1, 2, 1], vec![0.0, 1.0]);
        assert!(dice(&logits, &y_bad).unwrap() < 0.01);
    }

    #[test]
    fn span_f1_exact_and_partial() {
        // SEQ=4; gold span [1, 2].
        let s = t(&[1, 4], vec![0.0, 9.0, 0.0, 0.0]);
        let e = t(&[1, 4], vec![0.0, 0.0, 9.0, 0.0]);
        let y = t(&[1, 2], vec![1.0, 2.0]);
        assert!((span_f1(&s, &e, &y).unwrap() - 1.0).abs() < 1e-12);
        // Predicted [2, 3] overlaps 1 token: p = 1/2, r = 1/2 -> F1 = 1/2.
        let s2 = t(&[1, 4], vec![0.0, 0.0, 9.0, 0.0]);
        let e2 = t(&[1, 4], vec![0.0, 0.0, 0.0, 9.0]);
        assert!((span_f1(&s2, &e2, &y).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_perfect_random_inverted() {
        let y = t(&[4], vec![0.0, 0.0, 1.0, 1.0]);
        let perfect = t(&[4], vec![0.1, 0.2, 0.8, 0.9]);
        assert!((auc(&perfect, &y).unwrap() - 1.0).abs() < 1e-12);
        let inverted = t(&[4], vec![0.9, 0.8, 0.2, 0.1]);
        assert!(auc(&inverted, &y).unwrap() < 1e-12);
        let ties = t(&[4], vec![0.5, 0.5, 0.5, 0.5]);
        assert!((auc(&ties, &y).unwrap() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_labels() {
        let y = t(&[3], vec![1.0, 1.0, 1.0]);
        let s = t(&[3], vec![0.1, 0.5, 0.9]);
        assert_eq!(auc(&s, &y).unwrap(), 0.5);
    }

    #[test]
    fn compute_rejects_missing_outputs() {
        // Regression: `compute` indexed outputs[0]/outputs[1] unchecked
        // and panicked on a model with fewer outputs — the HTTP 500
        // path needs an Err, never a worker-thread panic.
        let y = t(&[1], vec![0.0]);
        let err = compute("top1", &[], &y).unwrap_err();
        assert!(err.to_string().contains("needs 1"), "{err}");
        let one = t(&[1, 4], vec![0.0; 4]);
        let err = compute("span_f1", &[one.clone()], &y).unwrap_err();
        assert!(err.to_string().contains("needs 2"), "{err}");
        let err = compute("detection", &[one], &y).unwrap_err();
        assert!(err.to_string().contains("needs 2"), "{err}");
        assert!(compute("nope", &[], &y).is_err());
    }

    #[test]
    fn empty_batches_error_instead_of_nan() {
        // Regression: top1/detection/span_f1 divided by a zero batch
        // size and returned NaN (now invalid JSON-adjacent garbage in
        // reports); they must bail.
        let empty = t(&[0, 4], vec![]);
        let y0 = t(&[0], vec![]);
        assert!(top1(&empty, &y0).is_err());
        assert!(detection(&empty, &t(&[0, 4], vec![]), &y0).is_err());
        assert!(span_f1(&empty, &empty, &y0).is_err());
    }

    #[test]
    fn shape_mismatches_error_instead_of_panicking() {
        let conf = t(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        let boxes = t(&[2, 4], vec![0.5; 8]);
        let y_short = t(&[5], vec![0.0; 5]); // wants 2*5 = 10
        assert!(detection(&conf, &boxes, &y_short).is_err());
        let logits = t(&[3, 2], vec![0.0; 6]);
        let y_bad = t(&[2], vec![0.0, 1.0]); // wants 3 labels
        assert!(top1(&logits, &y_bad).is_err());
        assert!(dice(&logits, &t(&[5], vec![0.0; 5])).is_err());
        // Non-binary mask values and empty masks error instead of
        // indexing out of bounds / reporting a perfect empty score.
        assert!(dice(&logits, &t(&[3], vec![0.0, 2.0, 1.0])).is_err());
        assert!(dice(&t(&[0, 2], vec![]), &t(&[0], vec![])).is_err());
        assert!(auc(&t(&[4], vec![0.0; 4]), &t(&[3], vec![0.0; 3])).is_err());
    }

    #[test]
    fn nan_logits_do_not_panic() {
        // total_cmp in argmax: a NaN logit is an answer (NaN sorts
        // greatest), not a comparator panic inside the serving worker.
        let logits = t(&[2, 3], vec![f32::NAN, 0.0, 1.0, 0.0, f32::NAN, 2.0]);
        let y = t(&[2], vec![0.0, 1.0]);
        let acc = top1(&logits, &y).unwrap();
        assert!((0.0..=1.0).contains(&acc));
    }
}
