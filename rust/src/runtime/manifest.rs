//! The artifact manifest: signatures of every AOT-compiled computation.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use crate::json::{self, Value};

/// Shape + dtype of one artifact input or output.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Value) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v
                .opt("name")
                .map(|n| n.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_default(),
            shape: v.get("shape")?.as_shape()?,
            dtype: v.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT artifact (an HLO-text file plus its signature).
#[derive(Debug, Clone)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub kind: String,
    pub model: Option<String>,
    pub tile: Option<usize>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Calibration artifacts: tap names in output order.
    pub taps: Vec<String>,
}

/// Per-model metadata (parameters, DNF taps, metric, batch sizes).
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub name: String,
    pub params: Vec<TensorSpec>,
    pub taps: Vec<TensorSpec>,
    pub metric: String,
    pub optimizer: String,
    pub batch_eval: usize,
    pub batch_train: usize,
    pub input_shape: Vec<usize>,
    pub target_shape: Vec<usize>,
    pub tiles: Vec<usize>,
    pub finetuned: bool,
    pub num_outputs: usize,
}

impl ModelInfo {
    pub fn num_params(&self) -> usize {
        self.params.len()
    }

    pub fn param_elements(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub finetune_tile: usize,
    pub figs1_rows: usize,
    pub models: BTreeMap<String, ModelInfo>,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| anyhow!("cannot read manifest in {dir:?}: {e}; run `make artifacts`"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let v = json::parse(text)?;
        let mut models = BTreeMap::new();
        for (name, mv) in v.get("models")?.as_obj()? {
            let specs = |key: &str| -> Result<Vec<TensorSpec>> {
                mv.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|p| {
                        Ok(TensorSpec {
                            name: p.get("name")?.as_str()?.to_string(),
                            shape: p.get("shape")?.as_shape()?,
                            dtype: "float32".to_string(),
                        })
                    })
                    .collect()
            };
            models.insert(
                name.clone(),
                ModelInfo {
                    name: name.clone(),
                    params: specs("params")?,
                    taps: specs("taps")?,
                    metric: mv.get("metric")?.as_str()?.to_string(),
                    optimizer: mv.get("optimizer")?.as_str()?.to_string(),
                    batch_eval: mv.get("batch_eval")?.as_usize()?,
                    batch_train: mv.get("batch_train")?.as_usize()?,
                    input_shape: mv.get("input_shape")?.as_shape()?,
                    target_shape: mv.get("target_shape")?.as_shape()?,
                    tiles: mv.get("tiles")?.as_shape()?,
                    finetuned: mv.get("finetuned")?.as_bool()?,
                    num_outputs: mv.get("num_outputs")?.as_usize()?,
                },
            );
        }

        let mut artifacts = BTreeMap::new();
        for av in v.get("artifacts")?.as_arr()? {
            let name = av.get("name")?.as_str()?.to_string();
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name,
                    file: dir.join(av.get("file")?.as_str()?),
                    kind: av
                        .opt("kind")
                        .map(|k| k.as_str().map(str::to_string))
                        .transpose()?
                        .unwrap_or_default(),
                    model: av
                        .opt("model")
                        .map(|m| m.as_str().map(str::to_string))
                        .transpose()?,
                    tile: av.opt("tile").map(|t| t.as_usize()).transpose()?,
                    inputs: av
                        .get("inputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    outputs: av
                        .get("outputs")?
                        .as_arr()?
                        .iter()
                        .map(TensorSpec::from_json)
                        .collect::<Result<_>>()?,
                    taps: av
                        .opt("taps")
                        .map(|t| -> Result<Vec<String>> {
                            t.as_arr()?
                                .iter()
                                .map(|s| Ok(s.as_str()?.to_string()))
                                .collect()
                        })
                        .transpose()?
                        .unwrap_or_default(),
                },
            );
        }

        Ok(Manifest {
            dir,
            finetune_tile: v.get("finetune_tile")?.as_usize()?,
            figs1_rows: v.get("figs1_rows")?.as_usize()?,
            models,
            artifacts,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelInfo> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("unknown model {name:?}"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "finetune_tile": 128, "figs1_rows": 100,
      "models": {"cnn": {
        "params": [{"name": "c1.w", "shape": [3,3,3,16]}],
        "taps": [{"name": "c1", "shape": [8192, 16]}],
        "metric": "top1", "optimizer": "adamw",
        "batch_eval": 32, "batch_train": 32,
        "input_shape": [16,16,3], "target_shape": [],
        "tiles": [8,32,128], "finetuned": true, "num_outputs": 1}},
      "artifacts": [{
        "name": "cnn_init", "file": "cnn_init.hlo.txt", "kind": "init",
        "model": "cnn",
        "inputs": [{"name": "key", "shape": [2], "dtype": "uint32"}],
        "outputs": [{"shape": [3,3,3,16], "dtype": "float32"}]}]
    }"#;

    #[test]
    fn parses_manifest() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        assert_eq!(m.finetune_tile, 128);
        let cnn = m.model("cnn").unwrap();
        assert_eq!(cnn.params[0].shape, vec![3, 3, 3, 16]);
        assert_eq!(cnn.metric, "top1");
        assert!(cnn.finetuned);
        let a = m.artifact("cnn_init").unwrap();
        assert_eq!(a.inputs[0].dtype, "uint32");
        assert_eq!(a.file, PathBuf::from("/tmp/a/cnn_init.hlo.txt"));
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn param_elements() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.model("cnn").unwrap().param_elements(), 3 * 3 * 3 * 16);
    }
}
