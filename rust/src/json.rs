//! Minimal JSON: a recursive-descent parser and a writer.
//!
//! Used for the artifact manifest produced by `python/compile/aot.py` and
//! for machine-readable experiment reports. Supports the full JSON value
//! model; numbers are kept as f64 (the manifest only contains shapes and
//! names, well within f64's exact-integer range). Non-finite numbers
//! serialize as `null` (JSON has no NaN/Infinity literals).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Value]> {
        match self {
            Value::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Ok(o),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Field access on an object.
    pub fn get(&self, key: &str) -> Result<&Value> {
        self.as_obj()?
            .get(key)
            .ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    /// Optional field access.
    pub fn opt(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(o) => o.get(key),
            _ => None,
        }
    }

    /// Shape helper: `[2, 3]` -> `vec![2, 3]`.
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literals: emitting `{n}`
                    // here used to produce documents (table2.json, every
                    // machine-readable report) that no parser — not even
                    // this crate's own — would accept. `null` is the
                    // interchange convention (Python's json module,
                    // serde_json's default float behaviour).
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(s, out),
            Value::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors for report writing.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Arr(items)
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("expected {:?} at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.literal("true", Value::Bool(true)),
            b'f' => self.literal("false", Value::Bool(false)),
            b'n' => self.literal("null", Value::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                c => bail!("expected ',' or '}}', got {:?}", c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                c => bail!("expected ',' or ']', got {:?}", c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let esc = self.peek()?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint"))?,
                            );
                        }
                        c => bail!("bad escape {:?}", c as char),
                    }
                }
                b => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("truncated utf-8"))?;
                    out.push_str(std::str::from_utf8(chunk)?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit()
                || b == b'-'
                || b == b'+'
                || b == b'.'
                || b == b'e'
                || b == b'E'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Value::Num(text.parse::<f64>()?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        b if b < 0x80 => 1,
        b if b >> 5 == 0b110 => 2,
        b if b >> 4 == 0b1110 => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert!(!arr[2].get("b").unwrap().as_bool().unwrap());
    }

    #[test]
    fn parses_unicode_escape() {
        let v = parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "éA");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let v = parse("\"héllo ✓\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ✓");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"abfp","shape":[4,64],"ok":true,"x":null,"f":1.25}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn shape_helper() {
        let v = parse("[3, 224, 224]").unwrap();
        assert_eq!(v.as_shape().unwrap(), vec![3, 224, 224]);
    }

    #[test]
    fn writer_nonfinite_roundtrips_as_null() {
        // Regression: `write!(out, "{n}")` emitted the literals `NaN` /
        // `inf` for non-finite f64, which no JSON parser accepts — every
        // report carrying a DNF'd metric became unreadable. They must
        // serialize as null and survive a writer -> parser round trip.
        let v = obj(vec![
            ("nan", num(f64::NAN)),
            ("pinf", num(f64::INFINITY)),
            ("ninf", num(f64::NEG_INFINITY)),
            ("ok", num(1.5)),
        ]);
        let text = v.to_string();
        let back = parse(&text).expect("writer output must be valid JSON");
        assert_eq!(back.get("nan").unwrap(), &Value::Null);
        assert_eq!(back.get("pinf").unwrap(), &Value::Null);
        assert_eq!(back.get("ninf").unwrap(), &Value::Null);
        assert_eq!(back.get("ok").unwrap().as_f64().unwrap(), 1.5);
        // Nested positions too (array elements inside reports).
        let a = arr(vec![num(f64::NAN), num(2.0)]);
        let back = parse(&a.to_string()).unwrap();
        assert_eq!(back.as_arr().unwrap()[0], Value::Null);
    }

    #[test]
    fn writer_escapes() {
        let v = Value::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn parses_manifest_like() {
        let src = r#"{"artifacts":[{"name":"cnn_init","inputs":[{"name":"key","shape":[2],"dtype":"uint32"}]}]}"#;
        let v = parse(src).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("name").unwrap().as_str().unwrap(), "cnn_init");
        assert_eq!(
            a.get("inputs").unwrap().as_arr().unwrap()[0]
                .get("shape")
                .unwrap()
                .as_shape()
                .unwrap(),
            vec![2]
        );
    }
}
