//! `abfp` — the launcher. One subcommand per paper experiment plus
//! pretraining and serving. Run `abfp help` for usage.

use std::sync::Arc;

use anyhow::{bail, Result};

use abfp::abfp::DeviceConfig;
use abfp::backend::BackendKind;
use abfp::cli::Args;
use abfp::config::SweepGrid;
use abfp::coordinator::{loadgen, BatchPolicy, HttpServer, Router, WorkerConfig};
use abfp::data::dataset_for;
use abfp::models;
use abfp::rng::Pcg64;
use abfp::runtime::Engine;
use abfp::sweep::{bits, energy, fig5, figs1, table2, table3};
use abfp::train::{Schedule, StepKind, Trainer};

const USAGE: &str = "\
abfp — Adaptive Block Floating-Point reproduction (Basumallik et al. 2022)

USAGE: abfp <command> [flags]

  pretrain      train FLOAT32 baselines for all six archetypes
                  --models a,b  --steps N  --ckpt DIR  --seed N
  sweep-table2  Table II / Fig 4 / Table S2 quality grids
                  --models a,b  --backend LIST  --repeats N  --samples N
                  --fast  --out DIR
  fig5          per-layer differential-noise stds (Fig 5 / S2)
                  --models cnn,ssd  --out DIR
                  --host [--backends LIST --tile N]  artifact-free
                  variant: one projection layer per numeric backend
  finetune      Table III / S3: QAT vs DNF at tile 128, gain 8
                  --models cnn,ssd  --steps N  --bits 8 (or 6)  --out DIR
  figs1         Fig S1 numeric error distributions + Appendix A
                  --repeats N  --rows N  --backends LIST  --out DIR
  bits          Fig 2 captured-bit windows + format roster  --out DIR
  energy        section VI ADC energy analysis         --out DIR
  serve         start the router; --http PORT exposes the HTTP/1.1
                  front door (POST /v1/models/{m}:predict, GET
                  /v1/models, /healthz, Prometheus /metrics; ctrl-d =
                  graceful shutdown). Without --http: in-process
                  closed-loop latency bench.
                  --models a,b  --requests N  --tile N  --gain G
                  --backend NAME  (--f32 = --backend float32)
                  --bind ADDR (default 0.0.0.0)  --batch N  --wait-ms MS
  bench-serve   serving benchmark: start the HTTP server over loopback
                  and drive it with the built-in load generator; report
                  achieved QPS + p50/p95 and per-model worker stats.
                  Default worker is the artifact-free echo harness
                  (--elems N  --delay-ms MS  --queue N); --models a,b
                  benches real artifact-backed workers instead.
                  --concurrency N  --requests N  --qps Q (0 = closed
                  loop)  --port P  --batch N  --wait-ms MS
  help          this text

Backends: float32 | abfp | fixed | bfp (comma lists and `all` accepted
where LIST is expected; --backend and --backends are interchangeable).
fixed = global-scale INT-b straw man; bfp = static per-tile
power-of-two block floating point (HBFP-like).

Common flags: --artifacts DIR (default artifacts), --ckpt DIR (default
checkpoints), --out DIR (default reports), --threads N (simulator
worker threads on serve and every sweep; default all cores — ADC noise
is coordinate-keyed, so results are bit-identical for any N).";

fn main() -> Result<()> {
    let args = Args::from_env()?;
    // `--threads N` caps the simulator worker pool everywhere (serve
    // workers, sweep matmuls, param staging). Absent/0 = all cores.
    // Purely a scheduling knob: outputs are bit-identical for any value
    // (coordinate-keyed ADC noise; see tests/determinism.rs).
    let threads = args.usize_or("threads", 0)?;
    if threads > 0 {
        abfp::parallel::set_default_threads(threads);
    }
    match args.command.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "sweep-table2" => cmd_table2(&args),
        "fig5" => cmd_fig5(&args),
        "finetune" => cmd_finetune(&args),
        "figs1" => cmd_figs1(&args),
        "bits" => cmd_bits(&args),
        "energy" => cmd_energy(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
}

fn engine(args: &Args) -> Result<Engine> {
    Engine::load(&args.str_or("artifacts", "artifacts"))
}

/// `--backend` and `--backends` are interchangeable on every command;
/// a typo'd selector errors instead of silently running the default.
fn backend_flag(args: &Args, default: &str) -> String {
    args.get("backend")
        .or_else(|| args.get("backends"))
        .unwrap_or(default)
        .to_string()
}

fn model_list(args: &Args) -> Vec<String> {
    args.list("models")
        .unwrap_or_else(|| models::MODEL_NAMES.iter().map(|s| s.to_string()).collect())
}

/// Per-model FLOAT32 pretraining budget (steps) — enough for each mini
/// archetype to reach a strong baseline on its synthetic task.
fn pretrain_steps(model: &str, flag: usize) -> usize {
    if flag > 0 {
        return flag;
    }
    match model {
        "cnn" => 500,
        "ssd" => 600,
        "unet" => 300,
        "gru" => 500,
        "bert" => 700,
        "dlrm" => 400,
        _ => 400,
    }
}

fn cmd_pretrain(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let ckpt = args.str_or("ckpt", "checkpoints");
    let steps_flag = args.usize_or("steps", 0)?;
    let seed = args.u64_or("seed", 1)?;
    for model in model_list(args) {
        let steps = pretrain_steps(&model, steps_flag);
        eprintln!("[pretrain] {model}: {steps} steps");
        let mut tr = Trainer::new(&eng, &model, seed)?;
        let ds = dataset_for(&model)?;
        let sched = Schedule::step_decay(1e-3, 0.3, steps.div_ceil(3));
        let logs = tr.run(
            StepKind::F32,
            ds.as_ref(),
            &mut Pcg64::seeded(0xdada + seed),
            steps,
            &sched,
            None,
            (steps / 10).max(1),
        )?;
        for l in &logs {
            eprintln!("  step {:>4}  loss {:.4}  lr {:.2e}", l.step, l.loss, l.lr);
        }
        let m = abfp::sweep::eval::eval_f32(&eng, &model, &tr.params, 256)?;
        eprintln!("  {model}: FLOAT32 metric = {m:.4}");
        tr.save_checkpoint(&format!("{ckpt}/{model}.ckpt"))?;
    }
    Ok(())
}

fn cmd_table2(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let ckpt = args.str_or("ckpt", "checkpoints");
    let out = args.str_or("out", "reports");
    let mut grid = if args.bool("fast") {
        SweepGrid::fast()
    } else {
        SweepGrid::default()
    };
    grid.repeats = args.usize_or("repeats", grid.repeats)?;
    grid.eval_samples = args.usize_or("samples", grid.eval_samples)?;
    let backends = BackendKind::parse_list(&backend_flag(args, "abfp"))?;
    let mut sweeps = Vec::new();
    for model in model_list(args) {
        eprintln!(
            "[table2] {model} (backends: {})",
            backends
                .iter()
                .map(|b| b.name())
                .collect::<Vec<_>>()
                .join(",")
        );
        let params = abfp::sweep::eval::load_pretrained(&eng, &model, &ckpt)?;
        sweeps.push(table2::sweep_model(
            &eng, &model, &params, &grid, &backends, true,
        )?);
    }
    table2::write_reports(&out, &sweeps, &grid)?;
    println!("{}", table2::render_table2(&sweeps, &grid));
    eprintln!("reports written to {out}/table2.md, table_s2.md, fig4.txt");
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let out = args.str_or("out", "reports");
    let gains = [1.0, 8.0, 16.0];
    if args.bool("host") {
        // Artifact-free variant: one projection layer per backend on
        // the Rust simulators (--backends selects, default all).
        let backends = BackendKind::parse_list(&backend_flag(args, "all"))?;
        let tile = args.usize_or("tile", 128)?;
        let rows = fig5::run_host(&backends, &gains, (8, 8, 8), tile, 0.5, 64)?;
        fig5::write_reports(&out, &rows, tile)?;
        println!("{}", fig5::render(&rows, tile));
        return Ok(());
    }
    let eng = engine(args)?;
    let ckpt = args.str_or("ckpt", "checkpoints");
    let sel = args
        .list("models")
        .unwrap_or_else(|| vec!["cnn".into(), "ssd".into()]);
    let bits_list = [(8, 8, 8), (6, 6, 8)];
    let rows = fig5::run(&eng, &ckpt, &sel, &gains, &bits_list, 0.5)?;
    fig5::write_reports(&out, &rows, eng.manifest.finetune_tile)?;
    println!("{}", fig5::render(&rows, eng.manifest.finetune_tile));
    Ok(())
}

fn cmd_finetune(args: &Args) -> Result<()> {
    let eng = engine(args)?;
    let ckpt = args.str_or("ckpt", "checkpoints");
    let out = args.str_or("out", "reports");
    let sel = args
        .list("models")
        .unwrap_or_else(|| vec!["cnn".into(), "ssd".into()]);
    let steps = args.usize_or("steps", 150)?;
    // Validated parse: bits < 2 would divide by zero in delta().
    let bsel = args.bits_or("bits", 8)?;
    let mut results = Vec::new();
    for model in sel {
        let mut cfg = table3::FinetuneCfg::paper((bsel, bsel, 8), steps);
        if model == "ssd" {
            cfg.dnf_top_k = Some(3); // paper: noise only on noisiest layers
        }
        eprintln!("[finetune] {model} bits {bsel}/{bsel}/8 steps {steps}");
        results.push(table3::finetune_model(&eng, &model, &ckpt, &cfg, true)?);
    }
    table3::write_reports(&out, &results)?;
    println!("{}", table3::render(&results));
    Ok(())
}

fn cmd_figs1(args: &Args) -> Result<()> {
    let out = args.str_or("out", "reports");
    let repeats = args.usize_or("repeats", 3)?;
    let rows = args.usize_or("rows", figs1::ROWS)?;
    let backends = BackendKind::parse_list(&backend_flag(args, "all"))?;
    let cells = figs1::run(
        &[8, 32, 128],
        &[1.0, 2.0, 4.0, 8.0, 16.0],
        &[0.0, 0.5],
        repeats,
        rows,
    )?;
    let backend_cells = figs1::run_backends(&backends, &[8, 32, 128], repeats, rows)?;
    figs1::write_reports(&out, &cells, &backend_cells, true, rows)?;
    println!("{}", figs1::render(&cells));
    println!("{}", figs1::render_backends(&backend_cells));
    Ok(())
}

fn cmd_bits(args: &Args) -> Result<()> {
    let out = args.str_or("out", "reports");
    bits::write_reports(&out)?;
    println!("{}", bits::render(8, 8, 8, 128, &[0, 1, 2, 3, 4]));
    Ok(())
}

fn cmd_energy(args: &Args) -> Result<()> {
    let out = args.str_or("out", "reports");
    energy::write_reports(&out)?;
    println!("{}", energy::render());
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let ckpt = args.str_or("ckpt", "checkpoints");
    let sel = args
        .list("models")
        .unwrap_or_else(|| vec!["bert".into(), "dlrm".into()]);
    let n_requests = args.usize_or("requests", 256)?;
    let backend = if args.bool("f32") {
        BackendKind::Float32
    } else {
        BackendKind::parse(&backend_flag(args, "abfp"))?
    };
    let device = DeviceConfig::new(
        args.usize_or("tile", 128)?,
        (8, 8, 8),
        args.f32_or("gain", 8.0)?,
        0.5,
    );
    let cfg = WorkerConfig {
        backend,
        device: Some(device),
        policy: BatchPolicy::new(args.usize_or("batch", 32)?, args.u64_or("wait-ms", 4)?),
        threads: args.usize_or("threads", 0)?,
    };
    // The serve manifest line: exact backend configuration, machine
    // readable, so a served deployment is reproducible from its log.
    eprintln!(
        "[serve] starting workers for {sel:?} backend-config {}",
        backend.build(device, 0).config_json().to_string()
    );
    let router = Router::start(&artifacts, &ckpt, &sel, cfg)?;

    // `--http PORT` (bare `--http` = 8080): serve network traffic until
    // stdin closes, then shut down gracefully and print the stats.
    let http_port = match args.get("http") {
        None => None,
        Some("true") => Some(8080),
        Some(_) => Some(args.port_or("http", 8080)?),
    };
    if let Some(port) = http_port {
        use std::io::IsTerminal;
        let bind = args.str_or("bind", "0.0.0.0");
        let router = Arc::new(router);
        let mut server = HttpServer::bind(router.clone(), &bind_addr(&bind, port))?;
        println!("listening on http://{}", server.addr());
        println!("  POST /v1/models/{{model}}:predict   GET /v1/models /healthz /metrics");
        if std::io::stdin().is_terminal() {
            // Interactive: ctrl-d drains gracefully. (Only when stdin is
            // a terminal — under systemd/docker/nohup stdin is /dev/null
            // and an unconditional read would return EOF immediately,
            // shutting the server down milliseconds after startup.)
            println!("ctrl-d (stdin EOF) shuts down gracefully");
            let mut sink = String::new();
            while std::io::stdin().read_line(&mut sink).unwrap_or(0) > 0 {
                sink.clear();
            }
            eprintln!("[serve] draining connections");
            server.shutdown();
            print_server_stats(&router)?;
        } else {
            println!("stdin is not a terminal: serving until the process is killed");
            loop {
                std::thread::park();
            }
        }
        return Ok(());
    }

    // No HTTP: drive a closed-loop in-process load, round-robin over
    // the served models.
    let t0 = std::time::Instant::now();
    let mut rng = Pcg64::seeded(0x5e12);
    let mut pending = Vec::new();
    for i in 0..n_requests {
        let model = &sel[i % sel.len()];
        let ds = dataset_for(model)?;
        let batch = ds.batch(&mut rng, 1);
        let example_shape: Vec<usize> = batch.x.shape()[1..].to_vec();
        let x = batch.x.clone().reshape(&example_shape).unwrap();
        pending.push(router.submit(model, x)?);
    }
    for rx in pending {
        rx.recv()??;
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "served {n_requests} requests in {wall:.2}s = {:.1} req/s",
        n_requests as f64 / wall
    );
    print_server_stats(&router)?;
    Ok(())
}

/// Join a bind address and port; IPv6 literals need bracket syntax
/// (`[::1]:8080` — a bare `::1:8080` does not parse).
fn bind_addr(bind: &str, port: u16) -> String {
    if bind.contains(':') && !bind.starts_with('[') {
        format!("[{bind}]:{port}")
    } else {
        format!("{bind}:{port}")
    }
}

fn print_server_stats(router: &Router) -> Result<()> {
    for model in router.served_models() {
        let s = router.stats(&model)?;
        println!(
            "  {model}: {} reqs ({} failed), {} batches ({} failed, mean {:.1}), exec {:.1} ms, p50 {:.1} ms, p95 {:.1} ms",
            s.requests,
            s.failed_requests,
            s.batches,
            s.failed_batches,
            s.mean_batch,
            s.mean_exec_ms,
            s.p50_ms,
            s.p95_ms
        );
    }
    Ok(())
}

/// `bench-serve`: the serving benchmark — HTTP server + load generator
/// over loopback, one process. The default worker is the artifact-free
/// echo harness so the serving stack itself (HTTP parse, router, dynamic
/// batcher, stats) is measurable on any checkout; `--models` swaps in
/// real artifact-backed workers.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    let requests = args.usize_or("requests", 256)?;
    let concurrency = args.usize_or("concurrency", 8)?;
    let qps = args.f32_or("qps", 0.0)? as f64;
    let policy =
        BatchPolicy::new(args.usize_or("batch", 32)?, args.u64_or("wait-ms", 4)?);
    let bind = args.str_or("bind", "127.0.0.1");
    let port = args.port_or("port", 0)?;

    // `targets` is every (model, in_elems) the load generator will
    // drive — all served models, not just the first, so nobody pays
    // worker startup for a model the bench then ignores.
    let (router, targets) = if let Some(sel) = args.list("models") {
        // Real artifact-backed workers (needs `make artifacts`).
        let backend = BackendKind::parse(&backend_flag(args, "abfp"))?;
        let device = DeviceConfig::new(
            args.usize_or("tile", 128)?,
            (8, 8, 8),
            args.f32_or("gain", 8.0)?,
            0.5,
        );
        let cfg = WorkerConfig {
            backend,
            device: Some(device),
            policy,
            threads: args.usize_or("threads", 0)?,
        };
        let router = Router::start(
            &args.str_or("artifacts", "artifacts"),
            &args.str_or("ckpt", "checkpoints"),
            &sel,
            cfg,
        )?;
        let mut targets = Vec::new();
        for model in sel {
            let ds = dataset_for(&model)?;
            let in_elems = ds.batch(&mut Pcg64::seeded(1), 1).x.len();
            targets.push((model, in_elems));
        }
        (router, targets)
    } else {
        // Echo harness: real batcher/stats/backpressure, host compute.
        let in_elems = args.usize_or("elems", 64)?;
        let queue = args.usize_or("queue", 64)?;
        let delay = std::time::Duration::from_millis(args.u64_or("delay-ms", 2)?);
        let router = Router::start_echo(
            &[("echo".to_string(), in_elems)],
            policy,
            queue,
            delay,
        )?;
        (router, vec![("echo".to_string(), in_elems)])
    };

    let router = Arc::new(router);
    let mut server = HttpServer::bind(router.clone(), &bind_addr(&bind, port))?;
    for (model, in_elems) in &targets {
        let spec = loadgen::LoadSpec {
            addr: server.addr().to_string(),
            model: model.clone(),
            in_elems: *in_elems,
            requests,
            concurrency,
            target_qps: qps,
        };
        eprintln!(
            "[bench-serve] {} x{} -> http://{}/v1/models/{}:predict ({})",
            requests,
            concurrency,
            server.addr(),
            model,
            if qps > 0.0 {
                format!("open loop @ {qps} qps")
            } else {
                "closed loop".to_string()
            }
        );
        let report = loadgen::run(&spec)?;
        println!("{model}: {}", report.render());
    }
    print_server_stats(&router)?;
    server.shutdown();
    Ok(())
}
