//! criterion-lite: a timing harness for `benches/` (the real criterion
//! crate is unavailable offline; `cargo bench` runs these with
//! `harness = false`).
//!
//! Methodology: warmup iterations, then timed samples; reports min /
//! median / p95 / mean and derived throughput. Deterministic iteration
//! counts keep runs comparable across the perf-pass iterations recorded
//! in EXPERIMENTS.md §Perf.

use std::time::Instant;

/// One benchmark's timing summary (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub min_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub mean_ns: f64,
}

impl BenchResult {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / (self.median_ns * 1e-9)
    }

    pub fn report(&self) -> String {
        format!(
            "{:<42} {:>10} {:>10} {:>10}  ({} samples)",
            self.name,
            fmt_ns(self.min_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.samples
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// The harness: `Bench::new("suite").run("case", iters, || work())`.
pub struct Bench {
    pub suite: String,
    pub results: Vec<BenchResult>,
    warmup: usize,
    samples: usize,
}

impl Bench {
    pub fn new(suite: &str) -> Bench {
        println!("\n== bench suite: {suite} ==");
        println!(
            "{:<42} {:>10} {:>10} {:>10}",
            "case", "min", "median", "p95"
        );
        Bench {
            suite: suite.to_string(),
            results: Vec::new(),
            warmup: 3,
            samples: 12,
        }
    }

    /// Override sampling (slow end-to-end cases use fewer samples).
    pub fn with_samples(mut self, warmup: usize, samples: usize) -> Bench {
        self.warmup = warmup;
        self.samples = samples;
        self
    }

    /// Time `f`, which performs `iters` internal iterations per sample.
    pub fn run<F: FnMut()>(&mut self, name: &str, iters: usize, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut times: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_nanos() as f64 / iters.max(1) as f64);
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let result = BenchResult {
            name: format!("{}/{}", self.suite, name),
            samples: self.samples,
            min_ns: times[0],
            median_ns: times[times.len() / 2],
            p95_ns: times[((times.len() - 1) as f64 * 0.95) as usize],
            mean_ns: times.iter().sum::<f64>() / times.len() as f64,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench::new("test").with_samples(1, 5);
        let mut acc = 0u64;
        let r = b
            .run("spin", 1000, || {
                for i in 0..1000u64 {
                    acc = black_box(acc.wrapping_add(i));
                }
            })
            .clone();
        assert!(r.min_ns > 0.0);
        assert!(r.median_ns >= r.min_ns);
        assert!(r.p95_ns >= r.median_ns);
    }

    #[test]
    fn formats_units() {
        assert_eq!(fmt_ns(500.0), "500ns");
        assert_eq!(fmt_ns(2_500.0), "2.50us");
        assert_eq!(fmt_ns(3_000_000.0), "3.00ms");
        assert_eq!(fmt_ns(2e9), "2.000s");
    }

    #[test]
    fn throughput_derivation() {
        let r = BenchResult {
            name: "x".into(),
            samples: 1,
            min_ns: 1e6,
            median_ns: 1e6,
            p95_ns: 1e6,
            mean_ns: 1e6,
        };
        assert!((r.throughput(1000.0) - 1e9 / 1e3).abs() < 1.0);
    }
}
