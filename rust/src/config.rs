//! Experiment configuration: the sweep grids of the paper's evaluation
//! and a TOML-subset parser for user config files.
//!
//! The paper's grid (section V-A): tile widths {8, 32, 128}, gains
//! {1, 2, 4, 8, 16}, bitwidths {6/6/8, 8/8/8}, ADC noise 0.5 LSB,
//! 10 repeats (3 for 3D U-Net). Those defaults are encoded here and can
//! be overridden from a config file or CLI flags.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::abfp::DeviceConfig;

/// The evaluation grid of Table II / Fig. 4.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepGrid {
    pub tiles: Vec<usize>,
    pub gains: Vec<f32>,
    pub bitwidths: Vec<(u32, u32, u32)>,
    pub noise_lsb: f32,
    pub repeats: usize,
    pub eval_samples: usize,
}

impl Default for SweepGrid {
    fn default() -> Self {
        SweepGrid {
            tiles: vec![8, 32, 128],
            gains: vec![1.0, 2.0, 4.0, 8.0, 16.0],
            bitwidths: vec![(6, 6, 8), (8, 8, 8)],
            noise_lsb: 0.5,
            repeats: 3,
            eval_samples: 256,
        }
    }
}

impl SweepGrid {
    /// A reduced grid for smoke runs and CI.
    pub fn fast() -> Self {
        SweepGrid {
            tiles: vec![8, 128],
            gains: vec![1.0, 8.0],
            bitwidths: vec![(8, 8, 8)],
            noise_lsb: 0.5,
            repeats: 1,
            eval_samples: 64,
        }
    }

    /// Enumerate every device configuration in the grid.
    pub fn configs(&self) -> Vec<DeviceConfig> {
        let mut out = Vec::new();
        for &n in &self.tiles {
            for &bits in &self.bitwidths {
                for &gain in &self.gains {
                    out.push(DeviceConfig::new(n, bits, gain, self.noise_lsb));
                }
            }
        }
        out
    }
}

/// A parsed TOML-subset document: `[section]` headers and
/// `key = value` lines (string, number, bool, [array]).
#[derive(Debug, Clone, Default)]
pub struct Config {
    sections: BTreeMap<String, BTreeMap<String, TomlValue>>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Num(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    fn parse(text: &str) -> Result<TomlValue> {
        let t = text.trim();
        if t == "true" {
            return Ok(TomlValue::Bool(true));
        }
        if t == "false" {
            return Ok(TomlValue::Bool(false));
        }
        if let Some(inner) = t.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let items = inner
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(TomlValue::parse)
                .collect::<Result<Vec<_>>>()?;
            return Ok(TomlValue::Arr(items));
        }
        if let Some(inner) = t
            .strip_prefix('"')
            .and_then(|s| s.strip_suffix('"'))
            .or_else(|| t.strip_prefix('\'').and_then(|s| s.strip_suffix('\'')))
        {
            return Ok(TomlValue::Str(inner.to_string()));
        }
        t.parse::<f64>()
            .map(TomlValue::Num)
            .map_err(|_| anyhow!("cannot parse value {t:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut cfg = Config::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                cfg.sections.entry(section.clone()).or_default();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            cfg.sections
                .entry(section.clone())
                .or_default()
                .insert(k.trim().to_string(), TomlValue::parse(v)?);
        }
        Ok(cfg)
    }

    pub fn load(path: &str) -> Result<Config> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.sections.get(section)?.get(key)
    }

    /// Build a sweep grid from the `[sweep]` section, with defaults.
    pub fn sweep_grid(&self) -> Result<SweepGrid> {
        let mut grid = SweepGrid::default();
        if let Some(TomlValue::Arr(a)) = self.get("sweep", "tiles") {
            grid.tiles = a
                .iter()
                .map(|v| Ok(v.as_f64()? as usize))
                .collect::<Result<_>>()?;
        }
        if let Some(TomlValue::Arr(a)) = self.get("sweep", "gains") {
            grid.gains = a
                .iter()
                .map(|v| Ok(v.as_f64()? as f32))
                .collect::<Result<_>>()?;
        }
        if let Some(v) = self.get("sweep", "noise_lsb") {
            grid.noise_lsb = v.as_f64()? as f32;
        }
        if let Some(v) = self.get("sweep", "repeats") {
            grid.repeats = v.as_f64()? as usize;
        }
        if let Some(v) = self.get("sweep", "eval_samples") {
            grid.eval_samples = v.as_f64()? as usize;
        }
        Ok(grid)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_grid_matches_paper() {
        let g = SweepGrid::default();
        assert_eq!(g.tiles, vec![8, 32, 128]);
        assert_eq!(g.gains, vec![1.0, 2.0, 4.0, 8.0, 16.0]);
        assert_eq!(g.bitwidths.len(), 2);
        // 3 tiles x 2 bitwidths x 5 gains = 30 device configs per model.
        assert_eq!(g.configs().len(), 30);
    }

    #[test]
    fn parses_toml_subset() {
        let cfg = Config::parse(
            "# comment\n[sweep]\ntiles = [8, 128] # inline\nrepeats = 5\n\
             noise_lsb = 0.0\n[serve]\nname = \"bert\"\nfast = true\n",
        )
        .unwrap();
        let g = cfg.sweep_grid().unwrap();
        assert_eq!(g.tiles, vec![8, 128]);
        assert_eq!(g.repeats, 5);
        assert_eq!(g.noise_lsb, 0.0);
        assert_eq!(
            cfg.get("serve", "name"),
            Some(&TomlValue::Str("bert".into()))
        );
        assert_eq!(cfg.get("serve", "fast"), Some(&TomlValue::Bool(true)));
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("[a]\nnot a kv line\n").is_err());
        assert!(Config::parse("[a]\nx = @bad\n").is_err());
    }

    #[test]
    fn empty_and_comments_ok() {
        let cfg = Config::parse("\n# only comments\n\n").unwrap();
        assert!(cfg.get("sweep", "tiles").is_none());
        assert_eq!(cfg.sweep_grid().unwrap(), SweepGrid::default());
    }
}
