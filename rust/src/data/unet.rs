//! Synthetic segmentation task: Gaussian blobs over noise.
//!
//! The input is a sum of 1–3 Gaussian bumps plus noise; the mask labels
//! pixels where the clean signal exceeds a threshold. Two classes, like
//! BraTS whole-tumor — the regime the paper finds robust under ABFP.

use super::Dataset;
use crate::rng::Pcg64;

pub const SIZE: usize = 16;
const THRESHOLD: f32 = 0.5;

pub struct Blobs;

impl Dataset for Blobs {
    fn input_shape(&self) -> Vec<usize> {
        vec![SIZE, SIZE, 1]
    }

    fn target_shape(&self) -> Vec<usize> {
        vec![SIZE, SIZE]
    }

    fn example(&self, rng: &mut Pcg64, x: &mut [f32], y: &mut [f32]) {
        let nblobs = 1 + rng.below(3) as usize;
        let mut clean = vec![0.0f32; SIZE * SIZE];
        for _ in 0..nblobs {
            let cx = rng.uniform(3.0, SIZE as f32 - 3.0);
            let cy = rng.uniform(3.0, SIZE as f32 - 3.0);
            let sigma = rng.uniform(1.5, 3.0);
            let amp = rng.uniform(0.7, 1.2);
            for i in 0..SIZE {
                for j in 0..SIZE {
                    let d2 = (i as f32 - cy).powi(2) + (j as f32 - cx).powi(2);
                    clean[i * SIZE + j] += amp * (-d2 / (2.0 * sigma * sigma)).exp();
                }
            }
        }
        for k in 0..SIZE * SIZE {
            x[k] = clean[k] + rng.normal() * 0.15;
            y[k] = if clean[k] > THRESHOLD { 1.0 } else { 0.0 };
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_are_binary_and_nonempty() {
        let ds = Blobs;
        let b = ds.batch(&mut Pcg64::seeded(5), 32);
        assert!(b.y.data().iter().all(|&v| v == 0.0 || v == 1.0));
        let fg: f64 = b.y.data().iter().map(|&v| v as f64).sum();
        let frac = fg / b.y.len() as f64;
        assert!(frac > 0.02 && frac < 0.6, "foreground fraction {frac}");
    }
}
