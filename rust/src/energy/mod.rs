//! The ADC energy model of Rekhi et al. [6] and the paper's section VI
//! energy analysis.
//!
//! Model: ADC energy per conversion scales as `E ∝ 2^b` with the output
//! bit count `b` (mixed-signal converter scaling); analog gain `G`
//! multiplies signal power, so energy scales linearly in `G`; the analog
//! MVM array computes `n` MACs per conversion, so throughput scales with
//! the tile width. The paper's headline: ABFP at (n=128, G=8, 8 output
//! bits) vs Rekhi's optimal (n=8, 12.5 bits) saves
//! `2^(12.5-8) / 8 ≈ 2.8x` ADC energy and runs `128/8 = 16x` more MACs
//! per cycle.

/// One analog design point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DesignPoint {
    /// Tile width (dot-product length per conversion).
    pub n: usize,
    /// ADC output bits (may be fractional: effective bits).
    pub adc_bits: f64,
    /// Analog gain.
    pub gain: f64,
}

impl DesignPoint {
    /// The paper's ABFP operating point for ResNet50 (section VI).
    pub fn abfp_resnet50() -> DesignPoint {
        DesignPoint {
            n: 128,
            adc_bits: 8.0,
            gain: 8.0,
        }
    }

    /// Rekhi et al.'s optimal for ResNet50 at <1% loss: 12.5 effective
    /// bits at tile width 8, unit gain.
    pub fn rekhi_optimal() -> DesignPoint {
        DesignPoint {
            n: 8,
            adc_bits: 12.5,
            gain: 1.0,
        }
    }

    /// Relative ADC energy per conversion: `2^bits * gain` (arbitrary
    /// units; only ratios are meaningful).
    pub fn adc_energy_per_conversion(&self) -> f64 {
        self.adc_bits.exp2() * self.gain
    }

    /// MACs performed per ADC conversion = tile width.
    pub fn macs_per_conversion(&self) -> f64 {
        self.n as f64
    }

    /// Relative ADC energy *per MAC* — the figure of merit.
    pub fn adc_energy_per_mac(&self) -> f64 {
        self.adc_energy_per_conversion() / self.macs_per_conversion()
    }

    /// MACs per clock on an `n x n` MVM array (footnote 4).
    pub fn macs_per_cycle(&self) -> f64 {
        (self.n * self.n) as f64
    }
}

/// Energy comparison of two design points (section VI arithmetic).
#[derive(Debug, Clone, Copy)]
pub struct Comparison {
    /// ADC-bit energy saving factor `2^(b_ref - b_new)`.
    pub bit_saving: f64,
    /// Energy increase from gain.
    pub gain_cost: f64,
    /// Net per-conversion energy saving.
    pub net_conversion_saving: f64,
    /// Per-MAC energy saving (includes tile-width amortization).
    pub per_mac_saving: f64,
    /// Throughput factor in MACs per cycle.
    pub throughput_factor: f64,
}

/// Compare `new` against `reference` (positive = `new` wins).
pub fn compare(new: DesignPoint, reference: DesignPoint) -> Comparison {
    let bit_saving = (reference.adc_bits - new.adc_bits).exp2();
    let gain_cost = new.gain / reference.gain;
    Comparison {
        bit_saving,
        gain_cost,
        net_conversion_saving: bit_saving / gain_cost,
        per_mac_saving: reference.adc_energy_per_mac() / new.adc_energy_per_mac(),
        throughput_factor: new.macs_per_cycle() / reference.macs_per_cycle(),
    }
}

/// ADC bits needed to capture a full `n`-wide dot product of
/// `b_w`/`b_x`-bit operands: `b_w + b_x + log2(n) - 1` (section III-B).
pub fn full_precision_bits(b_w: u32, b_x: u32, n: usize) -> f64 {
    b_w as f64 + b_x as f64 + (n as f64).log2() - 1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_numbers() {
        let cmp = compare(DesignPoint::abfp_resnet50(), DesignPoint::rekhi_optimal());
        // "The energy savings from reducing the ADC bits is 2^(12.5-8) ~ 23x"
        assert!((cmp.bit_saving - 22.627).abs() < 0.01, "{cmp:?}");
        // "...the energy increase with a gain of 8 is a factor of 8x"
        assert_eq!(cmp.gain_cost, 8.0);
        // "...overall our method reduces energy by a factor of ~2.8"
        assert!((cmp.net_conversion_saving - 2.8284).abs() < 0.01, "{cmp:?}");
        // "...executes 16x more multiply-accumulate operations per clock
        // cycle" — per MVM *row*; as full n x n arrays it is 16^2.
        assert!((cmp.throughput_factor - 256.0).abs() < 1e-9);
        let row_factor = DesignPoint::abfp_resnet50().n as f64
            / DesignPoint::rekhi_optimal().n as f64;
        assert_eq!(row_factor, 16.0);
    }

    #[test]
    fn per_mac_saving_includes_amortization() {
        let cmp = compare(DesignPoint::abfp_resnet50(), DesignPoint::rekhi_optimal());
        // Per-MAC: 2.83x conversion saving x 16x amortization.
        assert!((cmp.per_mac_saving - 2.8284 * 16.0).abs() < 0.1, "{cmp:?}");
    }

    #[test]
    fn energy_monotone_in_bits_and_gain() {
        let base = DesignPoint {
            n: 8,
            adc_bits: 8.0,
            gain: 1.0,
        };
        let more_bits = DesignPoint {
            adc_bits: 10.0,
            ..base
        };
        let more_gain = DesignPoint { gain: 4.0, ..base };
        assert!(more_bits.adc_energy_per_conversion() > base.adc_energy_per_conversion());
        assert!(more_gain.adc_energy_per_conversion() > base.adc_energy_per_conversion());
    }

    #[test]
    fn full_precision_bits_example() {
        // Paper: b_w = b_x = 8, n = 128 -> ~22 bits.
        assert!((full_precision_bits(8, 8, 128) - 22.0).abs() < 1e-9);
    }
}
