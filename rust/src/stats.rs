//! Streaming statistics: Welford mean/variance, fixed-range histograms,
//! and latency percentile sketches for the coordinator.

use crate::rng::Pcg64;

/// Welford online mean / variance / extrema.
#[derive(Debug, Clone, Default)]
pub struct Running {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Running {
    pub fn new() -> Self {
        Running {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, v: f64) {
        self.n += 1;
        let d = v - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn extend(&mut self, vs: impl IntoIterator<Item = f64>) {
        for v in vs {
            self.push(v);
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample standard deviation (n-1), the paper's Table S2 convention.
    pub fn sample_std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Fixed-range equal-width histogram over [lo, hi].
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<f64>,
    pub underflow: u64,
    pub overflow: u64,
    /// NaN inputs: counted here, never binned. (NaN fails both range
    /// checks, so it used to fall through to the in-range arm where
    /// `(NaN / w) as usize == 0` silently inflated `counts[0]` —
    /// corrupting DNF noise histograms whose fit range is NaN-blind.)
    pub nan: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Histogram {
            lo,
            hi,
            counts: vec![0.0; bins],
            underflow: 0,
            overflow: 0,
            nan: 0,
        }
    }

    pub fn bins(&self) -> usize {
        self.counts.len()
    }

    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.counts.len() as f64
    }

    pub fn push(&mut self, v: f64) {
        if v.is_nan() {
            self.nan += 1;
        } else if v < self.lo {
            self.underflow += 1;
            self.counts[0] += 1.0; // clamp into the edge bins
        } else if v >= self.hi {
            self.overflow += 1;
            let last = self.counts.len() - 1;
            self.counts[last] += 1.0;
        } else {
            let idx = ((v - self.lo) / self.bin_width()) as usize;
            let last = self.counts.len() - 1;
            self.counts[idx.min(last)] += 1.0;
        }
    }

    /// Bin center for index i.
    pub fn center(&self, i: usize) -> f64 {
        self.lo + (i as f64 + 0.5) * self.bin_width()
    }

    /// Additive smoothing (the paper's DNF adds 0.5 to every bin).
    pub fn smooth(&mut self, add: f64) {
        for c in &mut self.counts {
            *c += add;
        }
    }

    pub fn total(&self) -> f64 {
        self.counts.iter().sum()
    }
}

/// Reservoir of latency samples with exact percentiles (sufficient at
/// serving-bench scale; switches to sampling above `cap`).
#[derive(Debug, Clone)]
pub struct Percentiles {
    samples: Vec<f64>,
    cap: usize,
    seen: u64,
    rng: Pcg64,
}

impl Percentiles {
    pub fn new(cap: usize) -> Self {
        Percentiles {
            samples: Vec::new(),
            cap,
            seen: 0,
            // Deterministic private stream: sketches reproduce run over
            // run for the same push sequence.
            rng: Pcg64::new(cap as u64, 0x9e7c_e9e1),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.seen += 1;
        if self.samples.len() < self.cap {
            self.samples.push(v);
        } else {
            // Algorithm R: element `seen` replaces a uniform slot in
            // [0, seen); it survives with probability cap/seen, which
            // keeps the reservoir an unbiased sample of the stream.
            // (The previous `(seen * 2654435761) % seen` draw was
            // identically zero — only samples[0] ever updated — and
            // the multiply overflowed in debug builds.)
            let idx = self.rng.below(self.seen) as usize;
            if idx < self.cap {
                self.samples[idx] = v;
            }
        }
    }

    /// Sorted copy of the reservoir. Callers that need several
    /// quantiles (p50 + p95 per stats snapshot) should sort once here
    /// and read them with [`quantile_sorted`] — [`Self::quantile`]
    /// re-sorts on every call. Uses `total_cmp`, so a NaN in the sketch
    /// sorts last instead of panicking the comparator (the old
    /// `partial_cmp().unwrap()` took down whatever thread held the
    /// stats mutex).
    pub fn sorted_clone(&self) -> Vec<f64> {
        let mut s = self.samples.clone();
        s.sort_by(f64::total_cmp);
        s
    }

    /// One-off quantile (clones + sorts; see [`Self::sorted_clone`]).
    pub fn quantile(&self, q: f64) -> f64 {
        quantile_sorted(&self.sorted_clone(), q)
    }

    pub fn count(&self) -> u64 {
        self.seen
    }
}

/// Nearest-rank quantile over pre-sorted samples (0.0 when empty, so
/// downstream reports stay finite before traffic arrives).
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[((q * (sorted.len() - 1) as f64).round()) as usize]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_closed_form() {
        let mut r = Running::new();
        r.extend([1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(r.count(), 5);
        assert!((r.mean() - 3.0).abs() < 1e-12);
        assert!((r.variance() - 2.0).abs() < 1e-12);
        assert!((r.sample_std() - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(r.min(), 1.0);
        assert_eq!(r.max(), 5.0);
    }

    #[test]
    fn welford_single_sample() {
        let mut r = Running::new();
        r.push(7.0);
        assert_eq!(r.sample_std(), 0.0);
        assert_eq!(r.mean(), 7.0);
    }

    #[test]
    fn histogram_binning() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for i in 0..10 {
            h.push(i as f64 + 0.5);
        }
        assert!(h.counts.iter().all(|&c| c == 1.0));
        h.push(-1.0);
        h.push(100.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.counts[0], 2.0);
        assert_eq!(h.counts[9], 2.0);
    }

    #[test]
    fn histogram_nan_counted_not_binned() {
        // Regression: NaN fails both range checks, so it used to fall
        // through to `((v - lo)/w) as usize == 0` and silently land in
        // counts[0]. It must be counted apart and stay out of the bins.
        let mut h = Histogram::new(0.0, 10.0, 10);
        h.push(f64::NAN);
        h.push(f64::NAN);
        h.push(0.5);
        assert_eq!(h.nan, 2);
        assert_eq!(h.counts[0], 1.0);
        assert_eq!(h.total(), 1.0);
        assert_eq!(h.underflow, 0);
        assert_eq!(h.overflow, 0);
    }

    #[test]
    fn histogram_smoothing() {
        let mut h = Histogram::new(-1.0, 1.0, 4);
        h.push(0.0);
        h.smooth(0.5);
        assert_eq!(h.total(), 1.0 + 4.0 * 0.5);
    }

    #[test]
    fn histogram_centers() {
        let h = Histogram::new(-1.0, 1.0, 4);
        assert!((h.center(0) + 0.75).abs() < 1e-12);
        assert!((h.center(3) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn percentiles_exact_small() {
        let mut p = Percentiles::new(1000);
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert_eq!(p.quantile(0.0), 1.0);
        assert_eq!(p.quantile(1.0), 100.0);
        assert!((p.quantile(0.5) - 50.0).abs() <= 1.0);
        assert!((p.quantile(0.99) - 99.0).abs() <= 1.0);
    }

    #[test]
    fn quantile_survives_nan_in_the_sketch() {
        // Regression: `partial_cmp().unwrap()` panicked the sort if a
        // NaN ever entered the reservoir (poisoning the stats mutex in
        // the server). total_cmp sorts NaN last; finite quantiles stay
        // readable.
        let mut p = Percentiles::new(16);
        p.push(3.0);
        p.push(f64::NAN);
        p.push(1.0);
        p.push(2.0);
        assert_eq!(p.quantile(0.0), 1.0);
        assert!(p.quantile(1.0).is_nan()); // sorted last, visible at q=1
        let sorted = p.sorted_clone();
        assert_eq!(&sorted[..3], &[1.0, 2.0, 3.0]);
        assert!((quantile_sorted(&sorted[..3], 0.5) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn quantile_sorted_empty_is_finite() {
        assert_eq!(quantile_sorted(&[], 0.5), 0.0);
    }

    #[test]
    fn reservoir_tracks_the_whole_stream_past_cap() {
        // Regression for the broken reservoir draw: `(seen * K) % seen`
        // is always 0, so after the reservoir filled only samples[0]
        // was ever replaced and the sketch stayed frozen on the first
        // `cap` values. Push 1..=10_000 through a cap-64 sketch: an
        // unbiased reservoir's median must sit near 5_000, not near
        // the cap (the frozen sketch reported ~32).
        let mut p = Percentiles::new(64);
        for i in 1..=10_000 {
            p.push(i as f64);
        }
        assert_eq!(p.count(), 10_000);
        let p50 = p.quantile(0.5);
        assert!(
            (2_000.0..=8_000.0).contains(&p50),
            "median {p50} not tracking the stream"
        );
        // Late values must be able to enter the reservoir at all.
        assert!(p.quantile(1.0) > 64.0, "max {} frozen at the cap", p.quantile(1.0));
    }

    #[test]
    fn reservoir_keeps_roughly_cap_over_seen_of_late_values() {
        // Sharper distribution sanity: with cap 128 over 4096 pushes,
        // ~half the kept samples should come from the second half of
        // the stream (binomial(128, 1/2): far outside [32, 96] would
        // flag a biased draw).
        let mut p = Percentiles::new(128);
        for i in 0..4096 {
            p.push(i as f64);
        }
        let late = (0..=100)
            .map(|q| p.quantile(q as f64 / 100.0))
            .filter(|&v| v >= 2048.0)
            .count();
        assert!((25..=75).contains(&late), "late-quantile share {late}/101");
    }

    #[test]
    fn reservoir_panics_nowhere_in_debug_at_large_seen() {
        // The old draw multiplied `seen as usize * 2654435761`, which
        // overflows (and panics in debug builds) for large streams.
        let mut p = Percentiles::new(4);
        for _ in 0..4 {
            p.push(1.0);
        }
        p.seen = 1 << 40; // simulate a very long-lived worker
        for i in 0..16 {
            p.push(i as f64);
        }
        assert!(p.quantile(0.5).is_finite());
    }
}
