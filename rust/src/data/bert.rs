//! Synthetic span-extraction QA.
//!
//! The sequence starts with a query token `q` (drawn from a reserved
//! range), followed by random filler tokens; the unique answer span is
//! the contiguous triple `q q q` planted at a random position. The
//! target is `[start, end]`. The model must relate the query position to
//! the span via attention — a miniature of SQuAD extraction, with the
//! span-F1 metric of the paper.

use super::Dataset;
use crate::rng::Pcg64;

pub const VOCAB: u64 = 64;
pub const SEQ: usize = 32;
pub const SPAN_LEN: usize = 3;
/// Query tokens live in [1, 9); filler in [16, 64); 0 is [CLS]-like.
const QUERY_LO: u64 = 1;
const QUERY_HI: u64 = 9;
const FILLER_LO: u64 = 16;

pub struct SpanQa;

impl Dataset for SpanQa {
    fn input_shape(&self) -> Vec<usize> {
        vec![SEQ]
    }

    fn target_shape(&self) -> Vec<usize> {
        vec![2]
    }

    fn example(&self, rng: &mut Pcg64, x: &mut [f32], y: &mut [f32]) {
        let q = QUERY_LO + rng.below(QUERY_HI - QUERY_LO);
        x[0] = q as f32;
        for slot in x.iter_mut().skip(1) {
            *slot = (FILLER_LO + rng.below(VOCAB - FILLER_LO)) as f32;
        }
        let start = 2 + rng.below((SEQ - SPAN_LEN - 2) as u64) as usize;
        for t in 0..SPAN_LEN {
            x[start + t] = q as f32;
        }
        y[0] = start as f32;
        y[1] = (start + SPAN_LEN - 1) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_is_unique_query_run() {
        let ds = SpanQa;
        let b = ds.batch(&mut Pcg64::seeded(8), 64);
        for i in 0..64 {
            let row = &b.x.data()[i * SEQ..(i + 1) * SEQ];
            let (s, e) = (b.y.data()[i * 2] as usize, b.y.data()[i * 2 + 1] as usize);
            assert_eq!(e - s + 1, SPAN_LEN);
            let q = row[0];
            for t in s..=e {
                assert_eq!(row[t], q);
            }
            // No other occurrence of q outside [s, e] and position 0.
            for (t, &v) in row.iter().enumerate().skip(1) {
                if !(s..=e).contains(&t) {
                    assert_ne!(v, q, "row {i} pos {t}");
                }
            }
        }
    }
}
