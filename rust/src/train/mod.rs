//! Rust-driven training over AOT train-step artifacts.
//!
//! The Trainer owns model parameters and optimizer state as host tensors
//! and drives the `<model>_train_{f32,qat,dnf}` artifacts: one PJRT
//! execution per step, with data batching, learning-rate schedules and
//! loss-curve logging on the Rust side. This realizes the paper's whole
//! pipeline without Python: FLOAT32 pretraining ("the checkpoint"),
//! QAT (section IV-A) and DNF (section IV-B) finetuning.

mod schedule;

pub use schedule::{LrSchedule, Schedule};

use anyhow::{bail, Result};

use crate::data::Dataset;
use crate::models;
use crate::rng::Pcg64;
use crate::runtime::{
    lit_f32, lit_key, lit_scalar, lit_scalars, to_scalar, to_tensor, Engine,
    ModelInfo,
};
use crate::tensor::Tensor;

/// Which train-step artifact to drive.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepKind {
    /// FLOAT32 pretraining / baseline finetuning.
    F32,
    /// Quantization-aware training at the manifest's finetune tile:
    /// (gain, bits, noise_lsb) select the simulated device.
    Qat {
        gain: f32,
        bits: (u32, u32, u32),
        noise_lsb: f32,
    },
    /// Differential noise finetuning; noise tensors come from
    /// [`crate::dnf::NoiseModel::sample_taps`].
    Dnf,
}

/// Training state: parameters + optimizer moments + step counter.
pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub info: ModelInfo,
    pub params: Vec<Tensor>,
    opt_m: Vec<Tensor>,
    opt_v: Vec<Tensor>,
    step: f32,
    noise_seed: u64,
}

/// One recorded training step for EXPERIMENTS.md loss curves.
#[derive(Debug, Clone, Copy)]
pub struct StepLog {
    pub step: usize,
    pub loss: f64,
    pub lr: f32,
}

impl<'e> Trainer<'e> {
    /// Fresh model (runs the init artifact with `seed`).
    pub fn new(engine: &'e Engine, model: &str, seed: u64) -> Result<Trainer<'e>> {
        let info = engine.manifest.model(model)?.clone();
        let params = models::init_params(engine, &info, seed)?;
        Ok(Self::from_params(engine, info, params))
    }

    /// Resume from existing parameters.
    pub fn from_params(
        engine: &'e Engine,
        info: ModelInfo,
        params: Vec<Tensor>,
    ) -> Trainer<'e> {
        let zeros: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::zeros(p.shape()))
            .collect();
        Trainer {
            engine,
            info,
            opt_m: zeros.clone(),
            opt_v: zeros,
            params,
            step: 0.0,
            noise_seed: 0x7261_696e,
        }
    }

    pub fn load_checkpoint(&mut self, path: &str) -> Result<()> {
        let named = models::load_checkpoint(path)?;
        if named.len() != self.params.len() {
            bail!(
                "checkpoint has {} tensors, model wants {}",
                named.len(),
                self.params.len()
            );
        }
        for (i, spec) in self.info.params.iter().enumerate() {
            if named[i].0 != spec.name || named[i].1.shape() != &spec.shape[..] {
                bail!("checkpoint tensor {i} mismatch: {:?}", named[i].0);
            }
        }
        self.params = named.into_iter().map(|(_, t)| t).collect();
        self.reset_opt();
        Ok(())
    }

    pub fn save_checkpoint(&self, path: &str) -> Result<()> {
        let named: Vec<(String, Tensor)> = self
            .info
            .params
            .iter()
            .zip(&self.params)
            .map(|(s, t)| (s.name.clone(), t.clone()))
            .collect();
        models::save_checkpoint(path, &named)
    }

    /// Zero optimizer moments and the step counter (fresh finetune run).
    pub fn reset_opt(&mut self) {
        for t in self.opt_m.iter_mut().chain(self.opt_v.iter_mut()) {
            t.data_mut().fill(0.0);
        }
        self.step = 0.0;
    }

    fn artifact_name(&self, kind: StepKind) -> String {
        match kind {
            StepKind::F32 => models::art_train_f32(&self.info.name),
            StepKind::Qat { .. } => models::art_train_qat(
                &self.info.name,
                self.engine.manifest.finetune_tile,
            ),
            StepKind::Dnf => models::art_train_dnf(&self.info.name),
        }
    }

    /// Run one training step; `xi` supplies DNF noise tensors (tap order).
    pub fn step(
        &mut self,
        kind: StepKind,
        batch_x: &Tensor,
        batch_y: &Tensor,
        lr: f32,
        xi: Option<&[Tensor]>,
    ) -> Result<f64> {
        let exe = self.engine.executable(&self.artifact_name(kind))?;
        let p = self.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(3 * p + 8);
        for t in self.params.iter().chain(&self.opt_m).chain(&self.opt_v) {
            args.push(lit_f32(t)?);
        }
        args.push(lit_scalar(self.step));
        args.push(lit_f32(batch_x)?);
        args.push(lit_f32(batch_y)?);
        args.push(lit_scalar(lr));
        match kind {
            StepKind::F32 => {}
            StepKind::Qat {
                gain,
                bits,
                noise_lsb,
            } => {
                self.noise_seed = self.noise_seed.wrapping_add(1);
                args.push(lit_key(self.noise_seed));
                args.push(lit_scalars(gain, bits.0, bits.1, bits.2));
                args.push(lit_scalar(noise_lsb));
            }
            StepKind::Dnf => {
                let xi = xi.ok_or_else(|| anyhow::anyhow!("DNF needs xi"))?;
                if xi.len() != self.info.taps.len() {
                    bail!(
                        "expected {} xi tensors, got {}",
                        self.info.taps.len(),
                        xi.len()
                    );
                }
                for t in xi {
                    args.push(lit_f32(t)?);
                }
            }
        }
        let outs = exe.run(&args)?;
        // Output layout: params, m, v, step, loss.
        debug_assert_eq!(outs.len(), 3 * p + 2);
        for i in 0..p {
            self.params[i] = to_tensor(&outs[i])?;
            self.opt_m[i] = to_tensor(&outs[p + i])?;
            self.opt_v[i] = to_tensor(&outs[2 * p + i])?;
        }
        self.step = to_scalar(&outs[3 * p])?;
        Ok(to_scalar(&outs[3 * p + 1])? as f64)
    }

    /// Drive `steps` training steps over a dataset, returning the loss
    /// curve. DNF callers pass a sampler producing fresh xi per step.
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &mut self,
        kind: StepKind,
        ds: &dyn Dataset,
        data_rng: &mut Pcg64,
        steps: usize,
        schedule: &Schedule,
        mut xi_sampler: Option<&mut dyn FnMut() -> Result<Vec<Tensor>>>,
        log_every: usize,
    ) -> Result<Vec<StepLog>> {
        let b = self.info.batch_train;
        let mut logs = Vec::new();
        for s in 0..steps {
            let batch = ds.batch(data_rng, b);
            let lr = schedule.lr(s, steps);
            let xi = match &mut xi_sampler {
                Some(f) => Some(f()?),
                None => None,
            };
            let loss = self.step(kind, &batch.x, &batch.y, lr, xi.as_deref())?;
            if s % log_every.max(1) == 0 || s + 1 == steps {
                logs.push(StepLog { step: s, loss, lr });
            }
        }
        Ok(logs)
    }
}
