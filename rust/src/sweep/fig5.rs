//! Fig. 5 / Fig. S2: per-layer differential-noise standard deviations
//! for the two finetuned archetypes, across tile widths and gains.
//!
//! Note the paper computes these at both tile 8 and tile 128; our calib
//! artifact is compiled at the finetune tile (128), so the tile-8 column
//! is produced by the bit-exact Rust device simulator on the same layer
//! inputs — the two paths agree per the golden tests.

use anyhow::Result;

use crate::dnf;
use crate::data::dataset_for;
use crate::report::{bar_chart, write_report, Table};
use crate::rng::Pcg64;
use crate::runtime::Engine;
use crate::sweep::eval::load_pretrained;

/// One (model, bits, gain) row of layer stds.
#[derive(Debug, Clone)]
pub struct LayerStdRow {
    pub model: String,
    pub bits: (u32, u32, u32),
    pub gain: f32,
    pub layers: Vec<(String, f64)>,
}

/// Run the calibration artifact per gain and collect layer noise stds.
pub fn run(
    engine: &Engine,
    ckpt_dir: &str,
    models_sel: &[String],
    gains: &[f32],
    bits_list: &[(u32, u32, u32)],
    noise_lsb: f32,
) -> Result<Vec<LayerStdRow>> {
    let mut rows = Vec::new();
    for model in models_sel {
        let params = load_pretrained(engine, model, ckpt_dir)?;
        let info = engine.manifest.model(model)?.clone();
        let ds = dataset_for(model)?;
        let batch = ds.batch(&mut Pcg64::seeded(0xf1f5), info.batch_train);
        for &bits in bits_list {
            for &gain in gains {
                let nm = dnf::calibrate(
                    engine, model, &params, &batch.x, gain, bits, noise_lsb,
                    0xca11b,
                )?;
                rows.push(LayerStdRow {
                    model: model.clone(),
                    bits,
                    gain,
                    layers: nm
                        .layers
                        .iter()
                        .map(|l| (l.name.clone(), l.std))
                        .collect(),
                });
            }
        }
    }
    Ok(rows)
}

/// Render the Fig. 5 report (markdown table + ASCII chart per config).
pub fn render(rows: &[LayerStdRow], tile: usize) -> String {
    let mut out = format!(
        "## Fig. 5 — differential-noise std per layer (tile {tile})\n\n\
         The paper's observation to reproduce: at tile 128, the *first*\n\
         layer (and SSD's last heads) responds much more strongly to\n\
         gain 16 than the middle layers.\n\n"
    );
    for row in rows {
        let labels: Vec<String> =
            row.layers.iter().map(|(n, _)| n.clone()).collect();
        let values: Vec<f64> = row.layers.iter().map(|(_, s)| *s).collect();
        out.push_str(&bar_chart(
            &format!(
                "{} bits {}/{}/{} gain {}",
                row.model, row.bits.0, row.bits.1, row.bits.2, row.gain
            ),
            &labels,
            &values,
            40,
        ));
        out.push('\n');
    }
    let mut t = Table::new(
        "layer noise std (machine readable)",
        &["model", "bits", "gain", "layer", "std"],
    );
    for row in rows {
        for (layer, std) in &row.layers {
            t.row(vec![
                row.model.clone(),
                format!("{}/{}/{}", row.bits.0, row.bits.1, row.bits.2),
                row.gain.to_string(),
                layer.clone(),
                format!("{std:.6}"),
            ]);
        }
    }
    out.push_str(&t.to_markdown());
    out
}

pub fn write_reports(dir: &str, rows: &[LayerStdRow], tile: usize) -> Result<()> {
    write_report(dir, "fig5.md", &render(rows, tile))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_contains_layers_and_values() {
        let rows = vec![LayerStdRow {
            model: "cnn".into(),
            bits: (8, 8, 8),
            gain: 16.0,
            layers: vec![("c1".into(), 0.5), ("fc2".into(), 0.1)],
        }];
        let s = render(&rows, 128);
        assert!(s.contains("c1"));
        assert!(s.contains("0.500"));
        assert!(s.contains("gain 16"));
    }
}
