//! Device explorer: interactive-style CLI over the pure-Rust AMS device
//! simulator — sweep any (tile, bits, gain, noise) point and print the
//! error statistics and saturation behaviour, no artifacts required.
//!
//!   cargo run --release --example device_explorer -- \
//!       --tile 128 --bw 8 --bx 8 --by 8 --gain 8 --noise 0.5

use abfp::abfp::{matmul_error_stats, DeviceConfig};
use abfp::cli::Args;
use abfp::energy::{full_precision_bits, DesignPoint};
use abfp::numerics::BitWindow;
use abfp::sweep::figs1::protocol_inputs;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let tile = args.usize_or("tile", 128)?;
    let bw = args.usize_or("bw", 8)? as u32;
    let bx = args.usize_or("bx", 8)? as u32;
    let by = args.usize_or("by", 8)? as u32;
    let gain = args.f32_or("gain", 8.0)?;
    let noise = args.f32_or("noise", 0.5)?;
    let rows = args.usize_or("rows", 100)?;

    let cfg = DeviceConfig::new(tile, (bw, bx, by), gain, noise);
    println!("device: tile {tile}, bits {bw}/{bx}/{by}, gain {gain}, noise {noise} LSB");
    println!(
        "  output bin (1 LSB) = n*delta_y = {:.6}; clamp tau_Y = {}",
        cfg.output_bin(),
        tile
    );
    println!(
        "  full-precision output would need {:.1} bits; ADC has {by}",
        full_precision_bits(bw, bx, tile)
    );
    let g2 = (gain as f64).log2().round() as u32;
    let win = BitWindow::new(bw, bx, by, tile, g2);
    println!(
        "  bit window at G=2^{g2}: saturates {} MSBs, captures {}, loses {} LSBs",
        win.saturated_msbs,
        win.captured(),
        win.lost_lsbs()
    );

    let (x, w) = protocol_inputs(2022, rows);
    let s = matmul_error_stats(cfg, 7, &x, &w)?;
    println!("\nFig. S1 protocol ({rows}x768 @ 768x768, X~N(0,1), W~Laplace):");
    println!("  error mean {:+.3e}  std {:.3e}", s.mean, s.std);
    println!("  error extrema [{:+.3e}, {:+.3e}]", s.min, s.max);
    println!("  p01 {:+.3e}  p50 {:+.3e}  p99 {:+.3e}", s.p01, s.p50, s.p99);
    println!("  ADC saturation: {:.3}% of conversions", 100.0 * s.sat_frac);

    let dp = DesignPoint {
        n: tile,
        adc_bits: by as f64,
        gain: gain as f64,
    };
    println!(
        "\nenergy model: {:.3e} per conversion, {:.3e} per MAC (relative units)",
        dp.adc_energy_per_conversion(),
        dp.adc_energy_per_mac()
    );
    Ok(())
}
