//! The serving coordinator: request router + dynamic batcher + device
//! workers, fronted by a std-only HTTP/1.1 server (the
//! vLLM-router-shaped component of the stack).
//!
//! Architecture (one box per thread):
//!
//! ```text
//!   TCP clients -> HttpServer accept loop -> per-connection threads
//!      |                                          |  try_submit (429 on
//!      |                                          v   a full queue)
//!      |                                       Router ----> [ModelWorker "cnn"]
//!      |                                          |            (device thread:
//!   in-process clients --- submit(Request) ------+             Engine + batcher
//!                           -> oneshot Result<Response>        + PJRT executable)
//! ```
//!
//! Every worker runs one loop (`worker_main`) generic over
//! [`ModelExecutor`] — the serving-side twin of
//! [`NumericBackend`](crate::backend::NumericBackend). Three engines
//! plug in: [`EchoExecutor`] (identity compute, fault injection),
//! [`GraphExecutor`](crate::graph::GraphExecutor) (artifact-free
//! pure-Rust layer-graph inference with per-layer numeric plans —
//! [`Router::start_graph`]), and [`PjrtExecutor`] (AOT artifacts).
//! `PjRtClient` is thread-confined (Rc internals), so executors are
//! constructed by a factory *on* their dedicated worker thread — the
//! same discipline as one accelerator stream per model replica. The
//! batcher groups requests up to the executor's batch capacity or a
//! deadline, executes once, and fans results back out (the PJRT
//! executor pads to its compiled batch; padding rows cost nothing extra
//! because the artifact batch is fixed either way). An executor failure
//! fails the batch, not the worker: every waiting client gets an error
//! response and the failure is counted in [`ServerStats`].
//!
//! [`HttpServer`] speaks dependency-free HTTP/1.1 over
//! `std::net::TcpListener` (`POST /v1/models/{m}:predict`,
//! `GET /v1/models`, `GET /healthz`, Prometheus `GET /metrics`) with
//! keep-alive and graceful shutdown; [`loadgen`] drives it open- or
//! closed-loop over loopback and reports QPS / p50 / p95.

mod batcher;
mod executor;
mod http;
pub mod loadgen;
mod server;

pub use batcher::{collect_batch, BatchPolicy};
pub use executor::{
    EchoExecutor, Executed, ModelExecutor, PjrtExecutor, ECHO_FAIL_SENTINEL,
};
pub use http::HttpServer;
pub use server::{
    Request, Response, Router, ServerStats, SubmitError, WorkerConfig,
};
