//! E1: energy-model evaluation cost (trivially fast — included so every
//! experiment in DESIGN.md §5 has a bench target) plus a design-space
//! scan that mirrors the section VI analysis at scale.

use abfp::benchkit::{black_box, Bench};
use abfp::energy::{compare, DesignPoint};

fn main() {
    let mut b = Bench::new("energy");
    b.run("compare_1k_design_points", 1000, || {
        let mut acc = 0.0f64;
        for n_pow in 0..10u32 {
            for bits10 in 40..140u32 {
                let p = DesignPoint {
                    n: 1usize << n_pow,
                    adc_bits: bits10 as f64 / 10.0,
                    gain: 8.0,
                };
                acc += compare(p, DesignPoint::rekhi_optimal()).per_mac_saving;
            }
        }
        black_box(acc);
    });

    // Print the best design under the paper's accuracy-proxy constraint
    // (captured bits >= 8 after gain) as a scan artifact.
    let mut best: Option<(DesignPoint, f64)> = None;
    for n_pow in 3..8u32 {
        for g_pow in 0..5u32 {
            let p = DesignPoint {
                n: 1usize << n_pow,
                adc_bits: 8.0,
                gain: (1u64 << g_pow) as f64,
            };
            let e = p.adc_energy_per_mac();
            if best.map(|(_, be)| e < be).unwrap_or(true) {
                best = Some((p, e));
            }
        }
    }
    let (p, e) = best.unwrap();
    println!(
        "    -> min ADC energy/MAC at n={}, G={}: {:.3e} (relative)",
        p.n, p.gain, e
    );
}
