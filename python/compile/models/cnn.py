"""MiniCNN — the ResNet50/ImageNet archetype (Table I row 1).

A BN-free residual CNN classifying 16x16x3 synthetic grating images into
10 orientation classes. Convolutions run as ABFP tiled matmuls over
im2col patches (paper section V); per-channel scale/shift replaces
batch-norm (the paper reports BN folding makes no significant difference).

Reduction dims reach 288 (3x3x32 conv) and 256 (fc), so tile widths
{8, 32, 128} all exercise multi-tile accumulation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile import layers
from compile.models import common
from compile.models.common import Mode

NUM_CLASSES = 10
INPUT_SHAPE = (16, 16, 3)


def init(key):
    ks = jax.random.split(key, 16)
    p = {}
    p["c1.w"] = common.conv_init(ks[0], 3, 3, 3, 16)
    p["c1.b"] = common.zeros((16,))
    p["n1.g"], p["n1.b"] = common.ones((16,)), common.zeros((16,))
    # Residual block 1 (16 -> 16).
    p["b1c1.w"] = common.conv_init(ks[1], 3, 3, 16, 16)
    p["b1c1.b"] = common.zeros((16,))
    p["b1n.g"], p["b1n.b"] = common.ones((16,)), common.zeros((16,))
    p["b1c2.w"] = common.conv_init(ks[2], 3, 3, 16, 16)
    p["b1c2.b"] = common.zeros((16,))
    # Downsample (16 -> 32, stride 2).
    p["d1.w"] = common.conv_init(ks[3], 3, 3, 16, 32)
    p["d1.b"] = common.zeros((32,))
    p["d1n.g"], p["d1n.b"] = common.ones((32,)), common.zeros((32,))
    # Residual block 2 (32 -> 32).
    p["b2c1.w"] = common.conv_init(ks[4], 3, 3, 32, 32)
    p["b2c1.b"] = common.zeros((32,))
    p["b2n.g"], p["b2n.b"] = common.ones((32,)), common.zeros((32,))
    p["b2c2.w"] = common.conv_init(ks[5], 3, 3, 32, 32)
    p["b2c2.b"] = common.zeros((32,))
    # Classifier head.
    p["fc1.w"] = common.glorot(ks[6], (256, 32))
    p["fc1.b"] = common.zeros((256,))
    p["fc2.w"] = common.glorot(ks[7], (NUM_CLASSES, 256))
    p["fc2.b"] = common.zeros((NUM_CLASSES,))
    return p


def forward(p, x, mode: Mode):
    """x: (B, 16, 16, 3) -> (logits (B, 10),)."""
    h = mode.conv2d("c1", x, p["c1.w"], p["c1.b"], padding=1)
    h = layers.relu(layers.channel_scale(h, p["n1.g"], p["n1.b"]))

    skip = h
    h = mode.conv2d("b1c1", h, p["b1c1.w"], p["b1c1.b"], padding=1)
    h = layers.relu(layers.channel_scale(h, p["b1n.g"], p["b1n.b"]))
    h = mode.conv2d("b1c2", h, p["b1c2.w"], p["b1c2.b"], padding=1)
    h = layers.relu(h + skip)

    h = mode.conv2d("d1", h, p["d1.w"], p["d1.b"], stride=2, padding=1)
    h = layers.relu(layers.channel_scale(h, p["d1n.g"], p["d1n.b"]))

    skip = h
    h = mode.conv2d("b2c1", h, p["b2c1.w"], p["b2c1.b"], padding=1)
    h = layers.relu(layers.channel_scale(h, p["b2n.g"], p["b2n.b"]))
    h = mode.conv2d("b2c2", h, p["b2c2.w"], p["b2c2.b"], padding=1)
    h = layers.relu(h + skip)

    h = layers.avgpool_global(h)                       # (B, 32)
    h = layers.relu(mode.dense("fc1", h, p["fc1.w"], p["fc1.b"]))
    logits = mode.dense("fc2", h, p["fc2.w"], p["fc2.b"])
    return (logits,)


def loss(outputs, y):
    """Cross-entropy; y: (B,) class ids carried as float32."""
    (logits,) = outputs
    labels = layers.onehot(y.astype(jnp.int32), NUM_CLASSES)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(labels * logp, axis=-1))


MODEL = common.register(common.ModelDef(
    name="cnn",
    init=init,
    forward=forward,
    loss=loss,
    input_shape=INPUT_SHAPE,
    target_shape=(),
    batch_eval=32,
    batch_train=32,
    metric="top1",
    optimizer="adamw",
))
