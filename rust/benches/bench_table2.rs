//! Table II end-to-end cell cost: one ABFP evaluation pass per model
//! through the PJRT artifacts (the unit of work the sweep driver runs
//! 30x per model x repeats). Requires `make artifacts` + checkpoints
//! (falls back to init params so the bench always runs).

use abfp::abfp::DeviceConfig;
use abfp::benchkit::Bench;
use abfp::models;
use abfp::runtime::Engine;
use abfp::sweep::eval;

fn main() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP bench_table2: run `make artifacts` first");
        return;
    }
    let engine = Engine::load("artifacts").unwrap();
    let mut b = Bench::new("table2_cell").with_samples(1, 5);
    for model in ["cnn", "bert", "dlrm"] {
        let info = engine.manifest.model(model).unwrap().clone();
        let params = eval::load_pretrained(&engine, model, "checkpoints")
            .unwrap_or_else(|_| models::init_params(&engine, &info, 7).unwrap());
        for tile in [8usize, 128] {
            let cfg = DeviceConfig::new(tile, (8, 8, 8), 8.0, 0.5);
            // Warm the compile cache outside the timer.
            engine
                .executable(&models::art_fwd_abfp(model, tile))
                .unwrap();
            let r = b
                .run(&format!("{model}_t{tile}_64samples"), 1, || {
                    eval::eval_abfp(&engine, model, &params, cfg, 1, 64).unwrap();
                })
                .clone();
            println!(
                "    -> {:.1} samples/s",
                r.throughput(64.0)
            );
        }
    }
}
