//! Shared evaluation: run a model (FLOAT32 twin or ABFP device) over a
//! synthetic eval set and compute its task metric.

use anyhow::Result;

use crate::abfp::DeviceConfig;
use crate::data::dataset_for;
use crate::metrics;
use crate::models;
use crate::rng::Pcg64;
use crate::runtime::{lit_f32, lit_key, lit_scalars, to_tensor, Engine};
use crate::tensor::Tensor;

/// Evaluation seed base: the eval set is fixed across configs so Table II
/// cells are comparable (paper evaluates a fixed validation set).
pub const EVAL_DATA_SEED: u64 = 0xe7a1;

/// Evaluate the FLOAT32 twin.
pub fn eval_f32(
    engine: &Engine,
    model: &str,
    params: &[Tensor],
    samples: usize,
) -> Result<f64> {
    let info = engine.manifest.model(model)?.clone();
    let exe = engine.executable(&models::art_fwd_f32(model))?;
    let ds = dataset_for(model)?;
    let mut rng = Pcg64::seeded(EVAL_DATA_SEED);
    let b = info.batch_eval;
    let batches = samples.div_ceil(b);
    let mut metric_num = 0.0f64;
    for _ in 0..batches {
        let batch = ds.batch(&mut rng, b);
        let mut args: Vec<xla::Literal> =
            params.iter().map(lit_f32).collect::<Result<_>>()?;
        args.push(lit_f32(&batch.x)?);
        let outs = exe.run(&args)?;
        let tensors: Vec<Tensor> =
            outs.iter().map(to_tensor).collect::<Result<_>>()?;
        metric_num += metrics::compute(&info.metric, &tensors, &batch.y)?;
    }
    Ok(metric_num / batches as f64)
}

/// Evaluate under the ABFP device model; `noise_seed` perturbs the
/// simulated ADC noise (repeat with different seeds for Table S2).
pub fn eval_abfp(
    engine: &Engine,
    model: &str,
    params: &[Tensor],
    cfg: DeviceConfig,
    noise_seed: u64,
    samples: usize,
) -> Result<f64> {
    let info = engine.manifest.model(model)?.clone();
    let exe = engine.executable(&models::art_fwd_abfp(model, cfg.n))?;
    let ds = dataset_for(model)?;
    let mut rng = Pcg64::seeded(EVAL_DATA_SEED);
    let b = info.batch_eval;
    let batches = samples.div_ceil(b);
    let mut metric_num = 0.0f64;
    for bi in 0..batches {
        let batch = ds.batch(&mut rng, b);
        let mut args: Vec<xla::Literal> =
            params.iter().map(lit_f32).collect::<Result<_>>()?;
        args.push(lit_f32(&batch.x)?);
        args.push(lit_key(noise_seed.wrapping_mul(1000).wrapping_add(bi as u64)));
        args.push(lit_scalars(cfg.gain, cfg.bits_w, cfg.bits_x, cfg.bits_y));
        args.push(xla::Literal::scalar(cfg.noise_lsb));
        let outs = exe.run(&args)?;
        let tensors: Vec<Tensor> =
            outs.iter().map(to_tensor).collect::<Result<_>>()?;
        metric_num += metrics::compute(&info.metric, &tensors, &batch.y)?;
    }
    Ok(metric_num / batches as f64)
}

/// Load the pretrained checkpoint for a model (produced by `abfp
/// pretrain`), or fail with a actionable message.
pub fn load_pretrained(
    engine: &Engine,
    model: &str,
    ckpt_dir: &str,
) -> Result<Vec<Tensor>> {
    let path = format!("{ckpt_dir}/{model}.ckpt");
    let named = models::load_checkpoint(&path).map_err(|e| {
        anyhow::anyhow!("{e}; run `abfp pretrain --models {model}` first")
    })?;
    let info = engine.manifest.model(model)?;
    anyhow::ensure!(
        named.len() == info.params.len(),
        "checkpoint/manifest mismatch for {model}"
    );
    Ok(named.into_iter().map(|(_, t)| t).collect())
}
